"""Ragged grouped-LoRA kernel parity vs the pure-jnp oracle.

The ragged path (per-slot token-row counts; heterogeneous per-adapter
batch sizes fused in one step) must be EXACT: padded rows contribute
nothing to any output and receive zero gradient, full-width rows match
the dense kernels bitwise. Interpret mode on CPU is the CI harness.
"""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.grouped_lora import ops, ref

R = importlib.import_module("repro.kernels.grouped_lora.ragged")


def make(Z, T, din, r, dout, dtype=jnp.float32, with_base=True, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (Z, T, din), dtype)
    A = (0.1 * jax.random.normal(ks[1], (Z, din, r), jnp.float32)
         ).astype(dtype)
    B = (0.1 * jax.random.normal(ks[2], (Z, r, dout), jnp.float32)
         ).astype(dtype)
    scale = jnp.linspace(0.5, 2.0, Z)
    yb = (jax.random.normal(ks[3], (Z, T, dout), dtype)
          if with_base else None)
    return x, A, B, scale, yb


# (Z, T, din, r, dout, rows): aligned / odd shapes, empty groups, mixed T
CASES = [
    (1, 128, 256, 16, 256, (128,)),            # full (dense-degenerate)
    (2, 64, 96, 8, 80, (64, 17)),              # odd partial width
    (3, 100, 130, 12, 200, (100, 0, 41)),      # empty group in the middle
    (4, 256, 512, 64, 512, (256, 128, 8, 0)),  # mixed T per group
    (2, 7, 33, 4, 17, (5, 2)),                 # tiny unaligned everything
    (3, 40, 64, 8, 48, (0, 0, 0)),             # all groups empty
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_base", [True, False])
def test_ragged_forward_matches_ref(case, dtype, with_base):
    Z, T, din, r, dout, rows = case
    x, A, B, scale, yb = make(Z, T, din, r, dout, dtype, with_base)
    rows = jnp.asarray(rows, jnp.int32)
    got = ops.ragged_grouped_lora(x, A, B, scale, rows, yb, interpret=True)
    want = ref.ragged_lora_ref(x, A, B, scale, rows, yb)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("case", CASES[:4])
def test_ragged_gradients_match_ref(case):
    Z, T, din, r, dout, rows = case
    x, A, B, scale, yb = make(Z, T, din, r, dout, jnp.float32, True)
    rows = jnp.asarray(rows, jnp.int32)

    def loss_k(x, A, B, yb):
        return jnp.sum(jnp.tanh(ops.ragged_grouped_lora(
            x, A, B, scale, rows, yb, interpret=True)))

    def loss_r(x, A, B, yb):
        return jnp.sum(jnp.tanh(ref.ragged_lora_ref(
            x, A, B, scale, rows, yb)))

    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3))(x, A, B, yb)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3))(x, A, B, yb)
    for a, b, name in zip(gk, gr, ["dx", "dA", "dB", "dyb"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


def test_padded_rows_zero_delta_and_zero_grad():
    """Rows >= rows[z] must get a ZERO delta (y_base passthrough) and
    contribute nothing to dA/dB; their dX is zero."""
    Z, T, din, r, dout = 2, 32, 64, 8, 48
    x, A, B, scale, yb = make(Z, T, din, r, dout)
    rows = jnp.asarray([20, 7], jnp.int32)
    y = ops.ragged_grouped_lora(x, A, B, scale, rows, yb, interpret=True)
    for z, n in enumerate([20, 7]):
        np.testing.assert_array_equal(np.asarray(y[z, n:]),
                                      np.asarray(yb[z, n:]))

    def loss(x_, A_, B_):
        return jnp.sum(ops.ragged_grouped_lora(
            x_, A_, B_, scale, rows, interpret=True) ** 2)

    dx_, dA_, dB_ = jax.grad(loss, argnums=(0, 1, 2))(x, A, B)
    for z, n in enumerate([20, 7]):
        assert float(jnp.abs(dx_[z, n:]).max()) == 0.0
    # dA/dB from only the valid prefix: compare against truncated einsum
    want = jax.grad(
        lambda A_, B_: jnp.sum(ref.ragged_lora_ref(
            x, A_, B_, scale, rows) ** 2), argnums=(0, 1))(A, B)
    np.testing.assert_allclose(np.asarray(dA_), np.asarray(want[0]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dB_), np.asarray(want[1]),
                               rtol=2e-4, atol=2e-4)


def test_full_rows_bitwise_equal_dense():
    """rows == T everywhere must reproduce the dense kernels bitwise —
    the executor's dense-vs-ragged dispatch relies on it."""
    Z, T, din, r, dout = 3, 64, 96, 8, 80
    x, A, B, scale, yb = make(Z, T, din, r, dout)
    full = jnp.full((Z,), T, jnp.int32)
    d = ops.grouped_lora(x, A, B, scale, yb, interpret=True)
    rg = ops.ragged_grouped_lora(x, A, B, scale, full, yb, interpret=True)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(rg))


def test_individual_ragged_kernels_match_masked_einsum():
    Z, T, din, r, dout = 2, 128, 256, 16, 128
    x, A, B, scale, yb = make(Z, T, din, r, dout)
    rows = jnp.asarray([128, 37], jnp.int32)
    xm = ref._rows_mask(x, rows)
    s = R.xa(x, A, rows, interpret=True)
    np.testing.assert_allclose(np.asarray(s),
                               np.asarray(ref.grouped_xa_ref(xm, A)),
                               rtol=1e-5, atol=1e-5)
    dy = yb
    dym = ref._rows_mask(dy, rows)
    ds_ = R.ds(dy, B, scale, rows, interpret=True)
    want_ds = jnp.einsum("zto,zro->ztr", dym * scale[:, None, None], B)
    np.testing.assert_allclose(np.asarray(ds_), np.asarray(want_ds),
                               rtol=1e-5, atol=1e-5)
    dx_ = R.dx(ds_, A, rows, interpret=True)
    np.testing.assert_allclose(
        np.asarray(dx_), np.asarray(jnp.einsum("ztr,zdr->ztd", ds_, A)),
        rtol=1e-5, atol=1e-5)
    da_ = R.da(x, ds_, rows, interpret=True)
    np.testing.assert_allclose(
        np.asarray(da_), np.asarray(jnp.einsum("ztd,ztr->zdr", xm, ds_)),
        rtol=1e-4, atol=1e-4)
    db_ = R.db(s, dy, scale, rows, interpret=True)
    want_db = jnp.einsum("ztr,zto->zro", s, dym * scale[:, None, None])
    np.testing.assert_allclose(np.asarray(db_), np.asarray(want_db),
                               rtol=1e-4, atol=1e-4)


def test_lora_delta_ragged_context_dispatch():
    """core.lora: a ragged_rows binding routes lora_delta through the
    ragged path on every backend, and the jnp / pallas_interpret results
    agree (real rows exact, padded rows zero delta)."""
    from repro.core import lora as L
    Z, b, S, din, r, dout = 2, 4, 8, 32, 8, 24
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(ks[0], (Z, b, S, din))
    A = 0.1 * jax.random.normal(ks[1], (Z, din, r))
    B = 0.1 * jax.random.normal(ks[2], (Z, r, dout))
    scale = jnp.asarray([2.0, 0.5])
    rows = jnp.asarray([b * S, 2 * S], jnp.int32)   # slot 1: only 2 rows
    with L.ragged_rows(rows):
        y_jnp = L.lora_delta(x, A, B, scale)
        with L.backend("pallas_interpret"):
            y_pal = L.lora_delta(x, A, B, scale)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_pal),
                               rtol=1e-5, atol=1e-5)
    # padded rows (slot 1, batch rows >= 2) have zero delta on both paths
    assert float(jnp.abs(y_jnp[1, 2:]).max()) == 0.0
    assert float(jnp.abs(y_pal[1, 2:]).max()) == 0.0
    # without the binding, the jnp path computes a (nonzero) dense delta
    y_dense = L.lora_delta(x, A, B, scale)
    assert float(jnp.abs(y_dense[1, 2:]).max()) > 0.0
