"""ALTO's core soundness invariant: slot isolation.

Co-locating adapters on one backbone must not change any adapter's
gradients: slot z's grad depends only on slot z's data and params (the base
is frozen; the per-slot loss is a sum). This is what makes batched
multi-LoRA training equivalent to sequential training (paper §6.1) — and,
lifted one level, what makes CROSS-TASK co-location sound: two different
tasks' slots on one shared executor train exactly as each task would
alone (the executor-level tests at the bottom)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.core import lora as LORA
from repro.core.early_exit import EarlyExitConfig
from repro.core.executor import (SharedBackboneExecutor, TaskLifecycle,
                                 run_colocated)
from repro.core.losses import sft_loss
from repro.data.synthetic import SlotBatcher, make_task_dataset
from repro.models import model as M
from tests.conftest import reduced_f32


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_f32("paper-llama-tiny", num_layers=2, d_model=128,
                      vocab=128)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    Z = 3
    ranks = jnp.array([4, 8, 8])
    lt = LORA.init_lora_tree(key, cfg, Z, ranks, M.target_shapes(cfg))
    # make B nonzero so the adapters matter
    lt = jax.tree_util.tree_map(
        lambda x: x + 0.01 * jax.random.normal(key, x.shape), lt)
    lt = LORA.mask_lora_tree(lt, ranks, cfg.lora.r_max)
    tokens = jax.random.randint(key, (Z, 2, 16), 0, cfg.vocab_size)
    return cfg, params, lt, ranks, tokens


def grads_of(cfg, params, lt, tokens, active):
    def f(lora_):
        total, _ = sft_loss(cfg, params, lora_,
                            {"tokens": tokens, "labels": tokens},
                            active, remat=False)
        return total
    return jax.grad(f)(lt)


def test_grad_isolation_across_slots(setup):
    """Changing slot 2's data / params leaves slot 0-1 grads bit-identical."""
    cfg, params, lt, ranks, tokens = setup
    active = jnp.ones((3,), jnp.int32)
    g1 = grads_of(cfg, params, lt, tokens, active)
    # perturb slot 2's data AND params
    tokens2 = tokens.at[2].set((tokens[2] + 17) % cfg.vocab_size)
    lt2 = jax.tree_util.tree_map(
        lambda x: x.at[:, 2].mul(1.7) if x.ndim >= 2 else x, lt)
    g2 = grads_of(cfg, params, lt2, tokens2, active)
    for t in g1:
        for m in ("A", "B"):
            np.testing.assert_array_equal(np.asarray(g1[t][m][:, :2]),
                                          np.asarray(g2[t][m][:, :2]))


def test_inactive_slot_gets_zero_grad(setup):
    cfg, params, lt, ranks, tokens = setup
    active = jnp.array([1, 0, 1], jnp.int32)
    g = grads_of(cfg, params, lt, tokens, active)
    for t in g:
        for m in ("A", "B"):
            assert float(jnp.abs(g[t][m][:, 1]).max()) == 0.0


def test_colocated_equals_solo(setup):
    """Slot-z loss when co-located == loss when trained alone (Z=1)."""
    cfg, params, lt, ranks, tokens = setup
    active = jnp.ones((3,), jnp.int32)
    _, per = sft_loss(cfg, params, lt,
                      {"tokens": tokens, "labels": tokens}, active,
                      remat=False)
    for z in range(3):
        solo_lt = jax.tree_util.tree_map(lambda x: x[:, z:z + 1], lt)
        _, per_solo = sft_loss(cfg, params, solo_lt,
                               {"tokens": tokens[z:z + 1],
                                "labels": tokens[z:z + 1]},
                               jnp.ones((1,), jnp.int32), remat=False)
        np.testing.assert_allclose(float(per[z]), float(per_solo[0]),
                                   rtol=1e-5, atol=1e-6)


def test_rank_mask_invariance(setup):
    """An adapter padded from r=4 to r_max behaves exactly like rank 4."""
    cfg, params, lt, ranks, tokens = setup
    active = jnp.ones((3,), jnp.int32)
    _, per1 = sft_loss(cfg, params, lt,
                       {"tokens": tokens, "labels": tokens}, active,
                       remat=False)
    # scribble garbage into the masked region; re-mask; loss unchanged
    lt_dirty = jax.tree_util.tree_map(lambda x: x + 100.0, lt)
    lt_clean = LORA.mask_lora_tree(lt_dirty, ranks, cfg.lora.r_max)
    lt_fixed = jax.tree_util.tree_map(
        lambda clean, orig, dirty: jnp.where(jnp.abs(clean - dirty) > 0,
                                             orig, clean),
        lt_clean, lt, lt_dirty)
    # only the masked region differs between lt and lt_fixed... rebuild:
    # masked(lt + 100) has masked region = 0 == masked(lt); unmasked differs.
    # Instead: verify that masking dirty params zeroes exactly the pad.
    r_max = cfg.lora.r_max
    for t, ab in lt_clean.items():
        for z, rk in enumerate([4, 8, 8]):
            if rk >= r_max:
                continue   # full-rank slot: no padded region to check
            assert float(jnp.abs(ab["A"][:, z, :, rk:]).max()) == 0.0
            assert float(jnp.abs(ab["B"][:, z, rk:, :]).max()) == 0.0


# ---------------------------------------------------------------------------
# Executor-level cross-TASK isolation (shared-backbone co-location)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def exec_env():
    cfg = reduced_f32("paper-llama-tiny", num_layers=2, d_model=64,
                      vocab=128)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ds_a = make_task_dataset("task-a", cfg.vocab_size, seq_len=16,
                             num_train=32, num_val=8, difficulty=0.2, seed=1)
    ds_b = make_task_dataset("task-b", cfg.vocab_size, seq_len=16,
                             num_train=32, num_val=8, difficulty=0.6, seed=2)
    return cfg, params, ds_a, ds_b


def _lifecycle(ex, name, ds, seed, total_steps=8, width=None,
               ranks=(4, 8)):
    kw = {} if width is None else {"per_adapter_batch": width}
    jobs = {f"{name}/j{i}": TrainConfig(learning_rate=lr, lora_rank=rk,
                                        max_steps=total_steps, **kw)
            for i, (lr, rk) in enumerate(zip((3e-3, 1e-3), ranks))}
    ee = EarlyExitConfig(warmup_ratio=0.25, select_ratio=1.0)
    return TaskLifecycle(
        ex, name, jobs, total_steps, ee=ee, max_slots=2,
        batcher=SlotBatcher(ds, 2, ex.b, seed=seed), seed=seed)


def _run(cfg, params, lifecycle_specs, b_cap=2):
    """Fresh Z=4 shared executor; run the given tasks co-located.
    ``lifecycle_specs`` entries are (name, ds, seed) or
    (name, ds, seed, width) — width is the per-job batch size (ragged
    slots: tasks may differ)."""
    ex = SharedBackboneExecutor(cfg, params, Z=4, per_adapter_batch=b_cap,
                                eval_every=2, seed=0)
    lcs = [_lifecycle(ex, spec[0], spec[1], spec[2],
                      width=(spec[3] if len(spec) > 3 else None))
           for spec in lifecycle_specs]
    results = run_colocated(ex, lcs)
    hists = {spec[0]: {j: (tuple(m.val_hist), tuple(m.raw_train_hist))
                       for j, m in lc.monitors.items()}
             for spec, lc in zip(lifecycle_specs, lcs)}
    return results, hists


def test_cross_task_losses_bitwise_equal_solo(exec_env):
    """Two DIFFERENT tasks co-located on one shared executor produce
    bitwise-identical train/val loss histories — and therefore identical
    best-val results — to each task running alone (the loss-isolation
    property across task boundaries)."""
    cfg, params, ds_a, ds_b = exec_env
    fused, fused_h = _run(cfg, params,
                          [("A", ds_a, 3), ("B", ds_b, 4)])
    solo_a, solo_a_h = _run(cfg, params, [("A", ds_a, 3)])
    solo_b, solo_b_h = _run(cfg, params, [("B", ds_b, 4)])
    assert fused_h["A"] == solo_a_h["A"]      # bitwise: tuples of floats
    assert fused_h["B"] == solo_b_h["B"]
    assert fused["A"].best_val == solo_a["A"].best_val
    assert fused["B"].best_val == solo_b["B"].best_val
    assert fused["A"].best_job == solo_a["A"].best_job
    assert fused["B"].best_job == solo_b["B"].best_job


def test_ragged_cross_task_losses_bitwise_equal_solo(exec_env):
    """Tasks with DIFFERENT per-adapter batch sizes fused on one shared
    executor (ragged slots: A trains b=2, B trains b=4 in the same fused
    step) produce bitwise-identical train/val loss histories to each task
    running alone on the same-capacity replica — the loss-isolation
    property survives width heterogeneity."""
    cfg, params, ds_a, ds_b = exec_env
    specs = [("A", ds_a, 3, 2), ("B", ds_b, 4, 4)]
    fused, fused_h = _run(cfg, params, specs, b_cap=4)
    solo_a, solo_a_h = _run(cfg, params, [specs[0]], b_cap=4)
    solo_b, solo_b_h = _run(cfg, params, [specs[1]], b_cap=4)
    assert fused_h["A"] == solo_a_h["A"]      # bitwise: tuples of floats
    assert fused_h["B"] == solo_b_h["B"]
    assert fused["A"].best_val == solo_a["A"].best_val
    assert fused["B"].best_val == solo_b["B"].best_val
    # the narrow task really trained at its own width
    for r in fused["A"].job_results.values():
        assert r.samples_trained == r.steps_trained * 2
    for r in fused["B"].job_results.values():
        assert r.samples_trained == r.steps_trained * 4


def test_ragged_full_width_host_unperturbed_by_narrow_guest(exec_env):
    """A full-width task flips from the dense dispatch (alone) to the
    ragged dispatch (narrow co-tenant present) — its losses must not
    move a bit either way."""
    cfg, params, ds_a, ds_b = exec_env
    fused, fused_h = _run(cfg, params,
                          [("A", ds_a, 3, 4), ("B", ds_b, 4, 2)], b_cap=4)
    solo, solo_h = _run(cfg, params, [("A", ds_a, 3, 4)], b_cap=4)
    assert fused_h["A"] == solo_h["A"]
    assert fused["A"].best_val == solo["A"].best_val


def test_ragged_mixed_seq_len_cross_task_bitwise(exec_env):
    """Tasks with DIFFERENT seq lens (16 vs 8) — and different widths —
    fused on one seq_cap=16 executor: the short-seq guest's lanes pad
    mid-row (label masking keeps it exact) and both tasks' loss
    histories stay bitwise identical to running alone."""
    cfg, params, ds_a, _ = exec_env
    ds_short = make_task_dataset("task-c", cfg.vocab_size, seq_len=8,
                                 num_train=32, num_val=8, difficulty=0.4,
                                 seed=5)

    def run(specs):
        ex = SharedBackboneExecutor(cfg, params, Z=4, per_adapter_batch=4,
                                    eval_every=2, seed=0, seq_cap=16)
        lcs = [_lifecycle(ex, name, ds, seed, width=w)
               for name, ds, seed, w in specs]
        results = run_colocated(ex, lcs)
        hists = {lc.task_name: {j: (tuple(m.val_hist),
                                    tuple(m.raw_train_hist))
                                for j, m in lc.monitors.items()}
                 for lc in lcs}
        return results, hists

    specs = [("A", ds_a, 3, 4), ("C", ds_short, 5, 2)]
    fused, fused_h = run(specs)
    solo_a, solo_a_h = run([specs[0]])
    solo_c, solo_c_h = run([specs[1]])
    assert fused_h["A"] == solo_a_h["A"]
    assert fused_h["C"] == solo_c_h["C"]
    assert fused["A"].best_val == solo_a["A"].best_val
    assert fused["C"].best_val == solo_c["C"].best_val
    assert np.isfinite(fused["C"].best_val)


def test_ragged_slot_widths_tracked(exec_env):
    """While mixed-width tasks are co-resident, SlotManager carries each
    slot's own (b, seq) and the executor's token accounting sums them."""
    cfg, params, ds_a, ds_b = exec_env
    ex = SharedBackboneExecutor(cfg, params, Z=4, per_adapter_batch=4,
                                eval_every=2, seed=0)
    lc_a = _lifecycle(ex, "A", ds_a, 3, width=2)
    lc_b = _lifecycle(ex, "B", ds_b, 4, width=4)
    ex.add_task(lc_a)
    ex.add_task(lc_b)
    lc_a.begin()
    lc_b.begin()
    widths = {ex.slots.slot_b[s] for _, s in lc_a.resident.values()}
    assert widths == {2}
    widths_b = {ex.slots.slot_b[s] for _, s in lc_b.resident.values()}
    assert widths_b == {4}
    seq = ds_a.train.shape[1] - 1
    assert ex.slots.occupied_tokens() == (2 + 2 + 4 + 4) * seq
    ex.run_steps(2)
    assert ex.take_tokens() == 2 * (2 + 2 + 4 + 4) * seq
    # per-slot token widths surface for ChunkReport observability
    assert sorted(ex.slot_token_widths()) == sorted(
        [2 * seq, 2 * seq, 4 * seq, 4 * seq])


def test_cross_task_slot_tags(exec_env):
    """While co-located, every occupied slot is tagged with its owning
    task and the executor attributes it correctly."""
    cfg, params, ds_a, ds_b = exec_env
    ex = SharedBackboneExecutor(cfg, params, Z=4, per_adapter_batch=2,
                                eval_every=2, seed=0)
    lc_a = _lifecycle(ex, "A", ds_a, 3)
    lc_b = _lifecycle(ex, "B", ds_b, 4)
    ex.add_task(lc_a)
    ex.add_task(lc_b)
    lc_a.begin()
    lc_b.begin()
    assert set(ex.slots.occupied_of("A").values()) == {0, 1}
    assert set(ex.slots.occupied_of("B").values()) == {2, 3}
    # per-task adapter addressing over (possibly non-contiguous) slots
    for task, lc in (("A", lc_a), ("B", lc_b)):
        adapters = ex.slots.adapters_of(task)
        assert set(adapters) == set(lc.jobs)
        for job, tree in adapters.items():
            _, slot = lc.resident[job]
            ref = ex.slots.adapter_at(slot)
            for t in ref:
                np.testing.assert_array_equal(tree[t]["A"], ref[t]["A"])
    ex.run_steps(2)
    for lc in (lc_a, lc_b):
        for mon in lc.monitors.values():
            assert mon.steps_trained == 2


# ---------------------------------------------------------------------------
# rank-local isolation (mixed TRUE ranks on one replica)
# ---------------------------------------------------------------------------

def _run_ranked(cfg, params, lifecycle_specs, b_cap=2):
    """Fresh Z=4 shared executor; specs are (name, ds, seed, ranks)."""
    ex = SharedBackboneExecutor(cfg, params, Z=4, per_adapter_batch=b_cap,
                                eval_every=2, seed=0)
    lcs = [_lifecycle(ex, name, ds, seed, ranks=ranks)
           for name, ds, seed, ranks in lifecycle_specs]
    results = run_colocated(ex, lcs)
    hists = {lc.task_name: {j: (tuple(m.val_hist), tuple(m.raw_train_hist))
                            for j, m in lc.monitors.items()}
             for lc in lcs}
    return results, hists


def test_ranklocal_cross_task_losses_bitwise_equal_solo(exec_env):
    """Tasks with DIFFERENT true ranks (2/4 vs full-rank 8/8 on an
    r_max=8 executor) co-located on one shared executor produce bitwise-
    identical loss histories to each task alone. The full-rank task flips
    from the no-binding dispatch (alone) to the rank-local dispatch
    (low-rank co-tenant present) — its losses must not move a bit."""
    cfg, params, ds_a, ds_b = exec_env
    assert cfg.lora.r_max == 8
    specs = [("A", ds_a, 3, (2, 4)), ("B", ds_b, 4, (8, 8))]
    fused, fused_h = _run_ranked(cfg, params, specs)
    solo_a, solo_a_h = _run_ranked(cfg, params, [specs[0]])
    solo_b, solo_b_h = _run_ranked(cfg, params, [specs[1]])
    assert fused_h["A"] == solo_a_h["A"]      # bitwise: tuples of floats
    assert fused_h["B"] == solo_b_h["B"]
    assert fused["A"].best_val == solo_a["A"].best_val
    assert fused["B"].best_val == solo_b["B"].best_val
    assert np.isfinite(fused["A"].best_val)


def test_ranklocal_ragged_rank_and_width_compose_bitwise(exec_env):
    """Mixed ranks AND mixed widths at once: a rank-2/b=2 guest next to a
    full-rank/b=4 host rides the composed rank-local x ragged path; both
    tasks' loss histories stay bitwise identical to solo."""
    cfg, params, ds_a, ds_b = exec_env

    def run(specs):
        ex = SharedBackboneExecutor(cfg, params, Z=4, per_adapter_batch=4,
                                    eval_every=2, seed=0)
        lcs = [_lifecycle(ex, name, ds, seed, width=w, ranks=ranks)
               for name, ds, seed, w, ranks in specs]
        results = run_colocated(ex, lcs)
        hists = {lc.task_name: {j: (tuple(m.val_hist),
                                    tuple(m.raw_train_hist))
                                for j, m in lc.monitors.items()}
                 for lc in lcs}
        return results, hists

    specs = [("A", ds_a, 3, 4, (8, 8)), ("B", ds_b, 4, 2, (2, 4))]
    fused, fused_h = run(specs)
    solo_a, solo_a_h = run([specs[0]])
    solo_b, solo_b_h = run([specs[1]])
    assert fused_h["A"] == solo_a_h["A"]
    assert fused_h["B"] == solo_b_h["B"]
    assert fused["A"].best_val == solo_a["A"].best_val
    assert fused["B"].best_val == solo_b["B"].best_val


def test_ranklocal_slot_ranks_tracked(exec_env):
    """While mixed-rank tasks are co-resident, SlotManager mirrors each
    slot's TRUE rank on host, the executor's rank-token accounting sums
    them, and ChunkReport-style observability surfaces the vector."""
    cfg, params, ds_a, ds_b = exec_env
    ex = SharedBackboneExecutor(cfg, params, Z=4, per_adapter_batch=2,
                                eval_every=2, seed=0)
    lc_a = _lifecycle(ex, "A", ds_a, 3, ranks=(2, 4))
    lc_b = _lifecycle(ex, "B", ds_b, 4, ranks=(8, 8))
    ex.add_task(lc_a)
    ex.add_task(lc_b)
    lc_a.begin()
    lc_b.begin()
    ranks_a = sorted(ex.slots.slot_rank[s] for _, s in
                     lc_a.resident.values())
    ranks_b = sorted(ex.slots.slot_rank[s] for _, s in
                     lc_b.resident.values())
    assert ranks_a == [2, 4] and ranks_b == [8, 8]
    assert ex.slots.mixed_rank(cfg.lora.r_max)
    seq = ds_a.train.shape[1] - 1
    assert ex.slots.occupied_rank_tokens() == 2 * seq * (2 + 4 + 8 + 8)
    assert sorted(ex.slot_rank_vector()) == [2, 4, 8, 8]
    # host mirror agrees with the device ranks the train step consumes
    np.testing.assert_array_equal(np.asarray(ex.slots.ranks),
                                  np.asarray(ex.slots.slot_rank))
    # rank bounds feed the §A.3 rank-token budget
    assert lc_a.rank_bound() == 4 and lc_b.rank_bound() == 8
    assert lc_a.rank_tokens_bound() == lc_a.tokens_bound() * 4
    ex.run_steps(2)
    for lc in (lc_a, lc_b):
        for mon in lc.monitors.values():
            assert mon.steps_trained == 2


# ---------------------------------------------------------------------------
# SlotSnapshot migration: suspend on one replica, resume on another
# ---------------------------------------------------------------------------

def _drive(ex, lcs, steps=None):
    """Minimal coordinator (what run_colocated does, but stoppable mid-run
    so a task can be suspended between boundaries)."""
    done = 0
    while any(not lc.done for lc in lcs):
        live = [lc for lc in lcs if not lc.done]
        n = max(min(min(lc.steps_until_boundary() for lc in live),
                    ex.eval_every), 1)
        ex.run_steps(n)
        for lc in live:
            lc.on_steps(n)
        done += n
        if steps is not None and done >= steps:
            return


def _hists(lc):
    return {j: (tuple(m.val_hist), tuple(m.raw_train_hist))
            for j, m in lc.monitors.items()}


def _serve_env_build(exec_env):
    """Adapters + prompts for the inference-path isolation tests."""
    cfg, params, _, _ = exec_env
    key = jax.random.PRNGKey(2)
    ranks = [4, 8, 2]
    stack = LORA.init_lora_tree(key, cfg, 3, jnp.asarray(ranks),
                                M.target_shapes(cfg))
    stack = jax.tree_util.tree_map(
        lambda x: x + 0.01 * jax.random.normal(key, x.shape), stack)
    stack = LORA.mask_lora_tree(stack, jnp.asarray(ranks), cfg.lora.r_max)
    adapters = {z: jax.tree_util.tree_map(lambda x: np.asarray(x[:, z]),
                                          stack) for z in range(3)}
    rng = np.random.default_rng(11)
    prompts = {z: [rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
                   for _ in range(2)] for z in range(3)}
    return cfg, params, adapters, ranks, prompts


@pytest.fixture(scope="module")
def serve_env(exec_env):
    return _serve_env_build(exec_env)


def _serve_run(cfg, params, adapters, ranks, prompts, publish,
               on_step=None):
    """One serving round on a Z=3 pool with the given slots published;
    returns per-request token streams + recorded per-step logits."""
    from repro.serve import AdapterPool, ServeRequest, ServingReplica
    pool = AdapterPool(cfg, 3)
    for z in publish:
        pool.publish(f"a{z}", adapters[z], ranks[z], slot=z)
    rep = ServingReplica(cfg, params, pool, lanes=2, max_len=24)
    reqs = [ServeRequest(f"r{z}{i}", f"a{z}", prompts[z][i], 8)
            for z in publish for i in range(2)]
    stats = rep.serve_round(
        reqs, on_step=(on_step(pool) if on_step else None),
        record_logits=True)
    return {r.request_id: tuple(r.tokens) for r in reqs}, stats.logits, pool


def test_fused_decode_bitwise_equal_solo(serve_env):
    """The training-side isolation invariant lifted to the INFERENCE path:
    N adapters fused on one serving replica produce, for every request,
    decode logits (and therefore greedy continuations) bitwise identical
    to serving that adapter alone on the same-capacity replica — the
    other slots' contents never leak into a request's stream."""
    cfg, params, adapters, ranks, prompts = serve_env
    fused_toks, fused_log, _ = _serve_run(cfg, params, adapters, ranks,
                                          prompts, publish=[0, 1, 2])
    for z in range(3):
        solo_toks, solo_log, _ = _serve_run(cfg, params, adapters, ranks,
                                            prompts, publish=[z])
        for i in range(2):
            assert fused_toks[f"r{z}{i}"] == solo_toks[f"r{z}{i}"]
        assert len(fused_log) == len(solo_log)
        for (tf, lf), (ts, ls) in zip(fused_log, solo_log):
            assert tf == ts
            np.testing.assert_array_equal(lf[z], ls[z])   # bitwise


def test_hot_publish_retire_mid_decode_leaves_residents_unchanged(serve_env):
    """Hot publish into a free slot (and retire of another slot) BETWEEN
    decode steps of an in-flight round: the resident requests' logits and
    token streams do not move a bit, and the pool ends with the expected
    adapter set — serving's slot-isolation counterpart of the training
    suspend/resume guarantees."""
    cfg, params, adapters, ranks, prompts = serve_env

    def hook(pool):
        def on_step(step):
            if step == 3:
                pool.publish("a1", adapters[1], ranks[1], slot=1)
            if step == 6:
                pool.retire("a1")
                pool.publish("a2", adapters[2], ranks[2], slot=2)
        return on_step

    base_toks, base_log, _ = _serve_run(cfg, params, adapters, ranks,
                                        prompts, publish=[0])
    hot_toks, hot_log, pool = _serve_run(cfg, params, adapters, ranks,
                                         prompts, publish=[0],
                                         on_step=hook)
    assert base_toks == hot_toks
    assert len(base_log) == len(hot_log)
    for (tb, lb), (th, lh) in zip(base_log, hot_log):
        assert tb == th
        np.testing.assert_array_equal(lb[0], lh[0])       # bitwise
    assert pool.resident() == {"a0": 0, "a2": 2}
    assert pool.version == 4        # 1 initial + hot publish/retire/publish


def test_mid_decode_join_leaves_residents_bitwise_unchanged(serve_env):
    """The continuous-batching isolation invariant: requests JOINING free
    lanes mid-decode (block prefill into their own lane caches — or, on a
    ring cache, a k_pos-reset streamed join — while residents keep
    decoding) must not move a resident lane's logits or tokens by a bit.
    Covers same-slot lane reuse AND other-slot joins, non-ring and ring."""
    from repro.serve import AdapterPool, ServeRequest, ServingReplica

    cfg, params, adapters, ranks, prompts = serve_env

    def run(join, ring):
        pool = AdapterPool(cfg, 3)
        for z in range(3):
            pool.publish(f"a{z}", adapters[z], ranks[z], slot=z)
        rep = ServingReplica(cfg, params, pool, lanes=2, max_len=24,
                             ring=ring)
        resident = ServeRequest("res", "a0", prompts[0][0], 10)
        assert rep.try_join(resident)
        for step in range(24):
            if join and step == 4:          # mid-decode, lanes still live
                for z, i in ((0, 1), (1, 0), (2, 1)):
                    r = ServeRequest(f"j{z}{i}", f"a{z}", prompts[z][i], 6)
                    assert rep.try_join(r)
            rep.step_continuous(record_logits=True)
            if resident.done:
                break
        assert resident.done
        return (tuple(resident.tokens),
                [(t, lg[0, 0]) for t, lg in rep.step_logits])

    for ring in (False, True):
        toks_solo, log_solo = run(join=False, ring=ring)
        toks_join, log_join = run(join=True, ring=ring)
        assert toks_solo == toks_join
        assert len(log_solo) == len(log_join)
        for (ts, ls), (tj, lj) in zip(log_solo, log_join):
            assert ts == tj
            np.testing.assert_array_equal(ls, lj)          # bitwise


def test_migration_across_replicas_bitwise_equal(exec_env):
    """The migration primitive end to end: a task mid-training on replica 1
    is suspended (SlotSnapshot per resident job), restored on replica 2
    that already hosts a DIFFERENT resident mix (so the physical slots
    differ), and trained to completion — its train/val loss histories and
    best-val result are bitwise identical to never migrating."""
    cfg, params, ds_a, ds_b = exec_env
    ds_c = make_task_dataset("task-c", cfg.vocab_size, seq_len=16,
                             num_train=32, num_val=8, difficulty=0.4,
                             seed=3)

    def make_ex():
        return SharedBackboneExecutor(cfg, params, Z=4, per_adapter_batch=2,
                                      eval_every=2, seed=0)

    # solo baseline: A never migrates (co-located with B throughout)
    ex0 = make_ex()
    a0 = _lifecycle(ex0, "A", ds_a, 3)
    b0 = _lifecycle(ex0, "B", ds_b, 4)
    run_colocated(ex0, [a0, b0])
    ref = _hists(a0)

    # migration run: A starts on replica 1 (with B), moves mid-continue to
    # replica 2 where C is already mid-flight on different physical slots
    ex1, ex2 = make_ex(), make_ex()
    A = _lifecycle(ex1, "A", ds_a, 3)
    B = _lifecycle(ex1, "B", ds_b, 4)
    C = _lifecycle(ex2, "C", ds_c, 5)
    ex2.add_task(C)
    C.begin()
    _drive(ex2, [C], steps=4)           # C occupies replica 2's low slots
    ex1.add_task(A)
    ex1.add_task(B)
    A.begin()
    B.begin()
    _drive(ex1, [A, B], steps=4)        # A mid-flight on replica 1
    slots_before = {j: s for j, (_, s) in A.resident.items()}
    A.suspend()
    assert ex2.can_admit_task(A)        # capacity check works while suspended
    A.resume(ex2)
    slots_after = {j: s for j, (_, s) in A.resident.items()}
    assert set(slots_before.values()) != set(slots_after.values())
    _drive(ex2, [A, C])
    _drive(ex1, [B])
    assert _hists(A) == ref             # bitwise: tuples of floats
    assert A.result().best_val == a0.result().best_val
    assert A.result().best_job == a0.result().best_job
    # the bystanders were untouched too
    assert np.isfinite(C.result().best_val)
    assert np.isfinite(B.result().best_val)
