"""Linear-scan Pallas kernel vs the jnp chunked oracle (interpret mode):
shape/dtype/mode sweeps + gradient path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.linear_scan import ops
from repro.kernels.linear_scan.ref import linear_scan_ref


def make(B, S, K, V, seed=0, decay=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, S, K))
    k = jax.random.normal(ks[1], (B, S, K))
    v = jax.random.normal(ks[2], (B, S, V))
    logw = -decay * jnp.exp(jax.random.normal(ks[3], (B, S, K)))
    u = jax.random.normal(ks[4], (B, K))
    return q, k, v, logw, u


@pytest.mark.parametrize("B,S,K,V,chunk", [
    (2, 64, 8, 8, 16), (3, 32, 16, 8, 8), (1, 128, 8, 16, 32),
])
@pytest.mark.parametrize("mode", ["rwkv", "ssd"])
def test_kernel_matches_ref(B, S, K, V, chunk, mode):
    q, k, v, logw, u = make(B, S, K, V)
    doq = mode == "ssd"
    bonus = u if mode == "rwkv" else None
    y1, s1 = ops.linear_scan(q, k, v, logw, bonus=bonus,
                             decay_on_query=doq, chunk=chunk,
                             interpret=True)
    y2, s2 = linear_scan_ref(q, k, v, logw, bonus=bonus,
                             decay_on_query=doq, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=3e-4, atol=3e-4)


def test_kernel_initial_state_and_strong_decay():
    q, k, v, logw, u = make(2, 32, 8, 8, seed=3, decay=6.0)
    s0 = jax.random.normal(jax.random.PRNGKey(9), (2, 8, 8))
    y1, s1 = ops.linear_scan(q, k, v, logw, bonus=u, initial_state=s0,
                             chunk=8, interpret=True)
    y2, s2 = linear_scan_ref(q, k, v, logw, bonus=u, initial_state=s0,
                             chunk=8)
    assert bool(jnp.all(jnp.isfinite(y1)))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-3, atol=1e-3)


def test_kernel_bf16_inputs():
    q, k, v, logw, u = make(1, 32, 8, 8, seed=5)
    y1, s1 = ops.linear_scan(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                             v.astype(jnp.bfloat16), logw, bonus=u,
                             chunk=8, interpret=True)
    y2, s2 = linear_scan_ref(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                             v.astype(jnp.bfloat16), logw, bonus=u, chunk=8)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_gradient_path_matches_ref_autodiff():
    q, k, v, logw, u = make(1, 16, 4, 4, seed=7)

    def loss_kernel(q, k, v):
        y, s = ops.linear_scan(q, k, v, logw, bonus=u, chunk=8,
                               interpret=True)
        return jnp.sum(jnp.tanh(y)) + jnp.sum(s * s)

    def loss_ref(q, k, v):
        y, s = linear_scan_ref(q, k, v, logw, bonus=u, chunk=8)
        return jnp.sum(jnp.tanh(y)) + jnp.sum(s * s)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
