"""docs/ARCHITECTURE.md stays truthful: every internal link resolves and
every module path it names exists in the tree."""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
ARCH = ROOT / "docs" / "ARCHITECTURE.md"


def test_architecture_doc_exists_and_is_linked():
    assert ARCH.is_file()
    readme = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in readme


def test_architecture_internal_links_resolve():
    text = ARCH.read_text()
    links = re.findall(r"\]\(([^)#]+)(?:#[^)]*)?\)", text)
    internal = [ln for ln in links if not ln.startswith(("http://",
                                                         "https://"))]
    missing = [ln for ln in internal if not (ARCH.parent / ln).exists()
               and not (ROOT / ln).exists()]
    assert not missing, f"dead links in ARCHITECTURE.md: {missing}"


def test_architecture_module_paths_exist():
    text = ARCH.read_text()
    toks = set(re.findall(r"`([^`\s]+)`", text))
    paths = {tok for tok in toks if re.fullmatch(r"[\w./-]+", tok)
             and (tok.endswith((".py", ".md", ".json"))
                  or tok.startswith(("src/", "tests/", "benchmarks/",
                                     "docs/", "examples/")))}
    def exists(p):
        if "/" in p:
            return (ROOT / p).exists()
        # bare module names in the per-directory tables: anywhere in-tree
        return next(ROOT.glob(f"src/**/{p}"), None) is not None \
            or next(ROOT.glob(p), None) is not None
    missing = sorted(p for p in paths if not exists(p))
    assert not missing, f"ARCHITECTURE.md names missing paths: {missing}"
