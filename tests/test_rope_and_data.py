"""RoPE / M-RoPE properties + data pipeline (incl. DPO pair batcher)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import RoPEConfig
from repro.data.synthetic import (PairSlotBatcher, SlotBatcher,
                                  make_task_dataset)
from repro.models.rope import apply_rope, rope_angles, text_positions


def test_mrope_on_text_equals_rope():
    """M-RoPE with (t,t,t) positions must be exactly RoPE (paper property:
    text tokens degrade to 1-D rotary)."""
    hd = 32
    plain = RoPEConfig(theta=10_000.0)
    mrope = RoPEConfig(theta=10_000.0, mrope_sections=(8, 4, 4))
    S = 16
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 1, S, 2, hd))
    pos = text_positions((), S, plain)
    pos3 = text_positions((), S, mrope)
    a1 = rope_angles(pos, hd, plain)
    a2 = rope_angles(pos3, hd, mrope)
    # same angles only if section split preserves frequency order per
    # component position — for (t,t,t) all components use t, so angles for
    # the same frequency index must agree
    np.testing.assert_allclose(np.asarray(apply_rope(x, a1)),
                               np.asarray(apply_rope(x, a2)),
                               rtol=1e-6, atol=1e-6)


def test_rope_preserves_norm_and_relative_dot():
    hd, S = 16, 12
    cfg = RoPEConfig()
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 1, S, 1, hd))
    ang = rope_angles(text_positions((), S, cfg), hd, cfg)
    y = apply_rope(x, ang)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.PRNGKey(2), (hd,))
    k = jax.random.normal(jax.random.PRNGKey(3), (hd,))
    def dot_at(p, d):
        a = rope_angles(jnp.array([p, p + d]), hd, cfg)
        qk = apply_rope(jnp.stack([q, k])[None, :, None, :]
                        .reshape(1, 2, 1, hd), a)
        return float(jnp.sum(qk[0, 0, 0] * qk[0, 1, 0]))
    assert abs(dot_at(0, 3) - dot_at(7, 3)) < 1e-4


@settings(deadline=None, max_examples=20)
@given(n=st.integers(4, 40), b=st.integers(1, 5), z=st.integers(1, 4))
def test_property_slot_batcher_covers_dataset(n, b, z):
    ds = make_task_dataset("t", 64, seq_len=8, num_train=n, num_val=2)
    sb = SlotBatcher(ds, z, b, seed=1)
    seen = set()
    steps = (2 * n) // b + 1
    for _ in range(steps):
        toks, labels = sb.next_batch()
        assert toks.shape == (z, b, 8)
        np.testing.assert_array_equal(toks[:, :, 1:], labels[:, :, :-1])
        for row in toks.reshape(-1, 8):
            seen.add(row.tobytes())
    # after >= 2 epochs, every training row has appeared
    all_rows = {r[:-1].astype(np.int32).tobytes() for r in ds.train}
    assert all_rows <= seen


def test_pair_batcher_shapes_and_disjoint_sources():
    c = make_task_dataset("c", 64, seq_len=8, num_train=16, difficulty=0.1)
    r = make_task_dataset("r", 64, seq_len=8, num_train=16, difficulty=0.9,
                          seed=3)
    pb = PairSlotBatcher(c, r, Z=2, per_adapter_batch=3)
    d = pb.next_batch_dict()
    assert set(d) == {"tokens_chosen", "labels_chosen",
                      "tokens_rejected", "labels_rejected"}
    assert d["tokens_chosen"].shape == (2, 3, 8)
    vd = pb.val_batch_dict()
    assert vd["tokens_chosen"].shape[1] == vd["tokens_rejected"].shape[1]


def test_task_dataset_difficulty_orders_entropy():
    """Higher difficulty => higher empirical next-token entropy."""
    def entropy(ds):
        trans = {}
        for row in ds.train:
            for a, b in zip(row[:-1], row[1:]):
                trans.setdefault(int(a), []).append(int(b))
        hs = []
        for a, nxt in trans.items():
            if len(nxt) < 8:
                continue
            _, counts = np.unique(nxt, return_counts=True)
            p = counts / counts.sum()
            hs.append(-(p * np.log(p)).sum())
        return float(np.mean(hs))

    easy = make_task_dataset("e", 512, seq_len=32, num_train=128,
                             difficulty=0.05)
    hard = make_task_dataset("h", 512, seq_len=32, num_train=128,
                             difficulty=0.95)
    assert entropy(easy) < entropy(hard)
