"""End-to-end system behaviour: decode==forward consistency across families,
DPO loss path, HLO analyzer on a synthetic module."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lora as LORA
from repro.core.losses import dpo_loss
from repro.models import model as M
from repro.roofline import hlo as HLO
from tests.conftest import reduced_f32

ARCHS = ["stablelm-3b", "glm4-9b", "rwkv6-3b", "hymba-1.5b",
         "granite-moe-1b-a400m", "qwen2-vl-72b", "musicgen-medium"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = reduced_f32(arch)
    Z, b, S = 2, 1, 16
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    lt = LORA.init_lora_tree(key, cfg, Z, jnp.array([4, 8]),
                             M.target_shapes(cfg))
    lt = jax.tree_util.tree_map(lambda x: x + 0.01, lt)
    tokens = jax.random.randint(key, (Z, b, S), 0, cfg.vocab_size)
    h, _, _ = M.forward(cfg, params, lt, tokens, remat=False)
    logits_full = M._unembed(cfg, params, h[:, :, -1])
    cache = M.init_cache(cfg, Z, b, S)
    for t in range(S):
        logits_dec, cache = M.decode_step(cfg, params, lt, cache,
                                          tokens[:, :, t])
    np.testing.assert_allclose(np.asarray(logits_full),
                               np.asarray(logits_dec), rtol=2e-4, atol=2e-4)


def test_prefill_then_decode_continues_exactly():
    cfg = reduced_f32("stablelm-3b")
    Z, b, S = 1, 2, 16
    key = jax.random.PRNGKey(2)
    params = M.init_params(key, cfg)
    lt = LORA.init_lora_tree(key, cfg, Z, jnp.array([8]),
                             M.target_shapes(cfg))
    tokens = jax.random.randint(key, (Z, b, S), 0, cfg.vocab_size)
    # prefill first 8, then decode 8 one-by-one
    cache = M.init_cache(cfg, Z, b, S)
    h, _, cache = M.forward(cfg, params, lt, tokens[:, :, :8], cache=cache)
    assert int(cache["pos"]) == 8
    for t in range(8, S):
        logits_dec, cache = M.decode_step(cfg, params, lt, cache,
                                          tokens[:, :, t])
    h_full, _, _ = M.forward(cfg, params, lt, tokens, remat=False)
    logits_full = M._unembed(cfg, params, h_full[:, :, -1])
    np.testing.assert_allclose(np.asarray(logits_full),
                               np.asarray(logits_dec), rtol=2e-4, atol=2e-4)


def test_dpo_loss_runs_and_is_calibrated_at_init():
    cfg = reduced_f32("paper-llama-tiny", num_layers=2, d_model=128,
                      vocab=128)
    Z, b, S = 2, 2, 16
    key = jax.random.PRNGKey(3)
    params = M.init_params(key, cfg)
    lt = LORA.init_lora_tree(key, cfg, Z, jnp.array([4, 4]),
                             M.target_shapes(cfg))
    tok = lambda s: jax.random.randint(jax.random.PRNGKey(s), (Z, b, S), 0,
                                       cfg.vocab_size)
    batch = {"tokens_chosen": tok(1), "labels_chosen": tok(1),
             "tokens_rejected": tok(2), "labels_rejected": tok(2)}
    total, per = dpo_loss(cfg, params, lt, batch,
                          jnp.ones((Z,), jnp.int32), remat=False)
    assert per.shape == (Z,)
    assert bool(jnp.all(jnp.isfinite(per)))
    # fresh LoRA (B=0): policy == reference => margin 0 => loss = log 2
    np.testing.assert_allclose(np.asarray(per), np.log(2.0), rtol=1e-3)


def test_hlo_analyzer_on_synthetic_module():
    text = """HloModule test, is_scheduled=true

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %g = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%g, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,8] all-gather(%d), channel_id=1, replica_groups=[4,2]<=[8], dimensions={0}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ag)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  ROOT %c = pred[] constant(true)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %init = (s32[], f32[8,8]) tuple(%a, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %r = f32[8,8] get-tuple-element(%w), index=1
}
"""
    res = HLO.analyze(text)
    # dot: 2*8*8*8 = 1024 flops x 10 trips
    assert res["flops"] == 1024 * 10
    ag = res["collectives"]["all-gather"]
    assert ag["count"] == 10
    # (2-1)/2 * 256 bytes * 10
    assert abs(res["collective_traffic"] - 0.5 * 256 * 10) < 1e-6
