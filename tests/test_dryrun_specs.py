"""Fast dry-run front-end checks: every (arch x shape) combo must produce
consistent abstract inputs/state and legal partition specs — no compilation,
no faked devices (AbstractMesh only)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ASSIGNED, get_arch
from repro.configs.shapes import SHAPES
from repro.launch import partitioning as PT
from repro.launch.dryrun import abstract_state, input_specs
from repro.launch.mesh import abstract_mesh

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_input_specs_and_state(arch, shape):
    spec = input_specs(arch, shape)
    cfg = get_arch(arch)
    sh = SHAPES[shape]
    Z, b = sh.decompose()
    assert spec["Z"] == Z and spec["b"] == b
    if sh.kind in ("train", "prefill"):
        assert spec["batch"]["tokens"].shape == (Z, b, sh.seq_len)
        if cfg.input_mode == "mixed":
            me = spec["batch"]["modal_embeds"]
            assert me.shape[:2] == (Z, b) and me.shape[3] == cfg.d_model
    else:
        assert spec["tokens"].shape == (Z, b)
        assert "cache" in spec
        if cfg.family == "ssm":
            assert "wkv" in spec["cache"]["layers"]
        elif sh.name == "long_500k" and cfg.long_context_mode != "recurrent":
            # sub-quadratic: windowed ring cache, never a 512k KV buffer
            kshape = spec["cache"]["layers"]["attn"]["k"].shape
            assert kshape[3] <= cfg.sliding_window
    params, lora, opt = abstract_state(cfg, Z)
    # every lora leaf slot-stacked [L, Z, ...]
    for leaf in jax.tree_util.tree_leaves(lora):
        assert leaf.shape[0] == cfg.num_layers and leaf.shape[1] == Z


@pytest.mark.parametrize("arch", ["qwen2-vl-72b", "rwkv6-3b",
                                  "llama4-scout-17b-a16e"])
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["1pod", "2pod"])
def test_full_spec_pipeline_is_legal(arch, mesh):
    """base/lora/opt/batch/cache specs all resolve to dividing assignments."""
    cfg = get_arch(arch)
    spec = input_specs(arch, "decode_32k")
    params, lora, opt = abstract_state(cfg, spec["Z"])
    trees = [
        PT.base_param_specs(mesh, params),
        PT.lora_param_specs(mesh, lora),
        PT.cache_specs(mesh, spec["cache"]),
    ]
    leaves_and_specs = []
    for tree, specs in ((params, trees[0]), (lora, trees[1]),
                        (spec["cache"], trees[2])):
        leaves_and_specs += list(zip(
            jax.tree_util.tree_leaves(tree),
            jax.tree_util.tree_leaves(
                specs, is_leaf=lambda s: isinstance(s, P))))
    for leaf, s in leaves_and_specs:
        for dim, axes in enumerate(s):
            if axes is None:
                continue
            names = axes if isinstance(axes, tuple) else (axes,)
            n = 1
            for a in names:
                n *= mesh.shape[a]
            assert leaf.shape[dim] % n == 0, (arch, leaf.shape, s)
