"""Checkpoint roundtrip + slot extract/insert."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (extract_slot, insert_slot,
                                         load_pytree, save_pytree)


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32),
                       "c": jnp.zeros((1, 2), jnp.bfloat16)}}
    p = str(tmp_path / "ckpt.npz")
    save_pytree(p, tree, meta={"step": 7})
    restored, meta = load_pytree(p, tree)
    assert meta["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_slot_extract_insert():
    full = {"t": {"A": jnp.arange(24.0).reshape(2, 3, 4)}}  # [L=2, Z=3, 4]
    one = extract_slot(full, 1)
    assert one["t"]["A"].shape == (2, 4)
    np.testing.assert_array_equal(np.asarray(one["t"]["A"]),
                                  np.asarray(full["t"]["A"][:, 1]))
    zeroed = insert_slot(full, 1, {"t": {"A": jnp.zeros((2, 4))}})
    assert float(jnp.abs(zeroed["t"]["A"][:, 1]).max()) == 0.0
    np.testing.assert_array_equal(np.asarray(zeroed["t"]["A"][:, 0]),
                                  np.asarray(full["t"]["A"][:, 0]))
