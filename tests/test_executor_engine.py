"""Integration: BatchedExecutor + Engine end-to-end on a tiny model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as alto
from repro.core.adapter_state import SlotManager
from repro.core.early_exit import EarlyExitConfig
from repro.core.executor import BatchedExecutor
from repro.configs.base import TrainConfig
from repro.data.synthetic import SlotBatcher, make_task_dataset
from repro.models import model as M
from tests.conftest import reduced_f32


@pytest.fixture(scope="module")
def env():
    cfg = reduced_f32("paper-llama-tiny", num_layers=2, d_model=128,
                      vocab=256)
    ds = make_task_dataset("t", cfg.vocab_size, seq_len=32, num_train=64,
                           num_val=16, difficulty=0.2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, ds, params


def test_slot_snapshot_restore_bit_exact(env):
    cfg, ds, params = env
    mgr = SlotManager(cfg, 2, M.target_shapes(cfg), jax.random.PRNGKey(1))
    tc = TrainConfig(learning_rate=3e-3, lora_rank=4)
    mgr.admit(0, "job-a", tc, jax.random.PRNGKey(2))
    before = jax.tree_util.tree_map(np.asarray, mgr.lora)
    snap = mgr.snapshot(0)
    mgr.evict(0)
    assert mgr.slot_jobs[0] is None
    assert float(jnp.abs(mgr.lora["q_proj"]["A"][:, 0]).max()) == 0.0
    mgr.restore(0, snap, tc)
    after = jax.tree_util.tree_map(np.asarray, mgr.lora)
    for t in before:
        np.testing.assert_array_equal(before[t]["A"], after[t]["A"])
        np.testing.assert_array_equal(before[t]["B"], after[t]["B"])


def test_executor_full_lifecycle(env):
    cfg, ds, params = env
    ex = BatchedExecutor(cfg, params, ds, Z=2, per_adapter_batch=4,
                         ee=EarlyExitConfig(warmup_ratio=0.2,
                                            select_ratio=0.5),
                         eval_every=2, seed=0)
    jobs = {
        "good": TrainConfig(learning_rate=3e-3, lora_rank=8, max_steps=20),
        "lowlr": TrainConfig(learning_rate=1e-6, lora_rank=4, max_steps=20),
        "crazy": TrainConfig(learning_rate=500.0, lora_rank=8, max_steps=20),
        "ok": TrainConfig(learning_rate=1e-3, lora_rank=4, max_steps=20),
    }
    res = ex.run_task("task", jobs, total_steps=20)
    assert res.best_job in jobs
    assert np.isfinite(res.best_val)
    assert res.job_results[res.best_job].adapter is not None
    # every job got a terminal status
    for r in res.job_results.values():
        assert r.exit_reason is not None
    # warmup rotation trained every candidate at least warmup steps
    for r in res.job_results.values():
        assert r.steps_trained >= 4
    # early exit saved samples vs full grid
    assert 0.0 <= res.samples_saved_frac < 1.0


def test_diverging_lr_is_culled_by_patterns(env):
    """A genuinely diverging job must exit with fewer steps than budget."""
    cfg, ds, params = env
    ex = BatchedExecutor(cfg, params, ds, Z=2, per_adapter_batch=4,
                         ee=EarlyExitConfig(warmup_ratio=0.1,
                                            select_ratio=1.0),
                         eval_every=2, seed=0)
    jobs = {
        "good": TrainConfig(learning_rate=3e-3, lora_rank=8, max_steps=30),
        "diverge": TrainConfig(learning_rate=1000.0, lora_rank=8,
                               max_steps=30, grad_clip=0.0),
    }
    res = ex.run_task("task", jobs, total_steps=30)
    dj = res.job_results["diverge"]
    assert dj.exit_reason is not None
    # ALTO's contract: whoever wins, the winner ships the checkpoint of its
    # BEST validation point (a diverging config may legitimately win with
    # its pre-divergence best — paper §5.1 best-val checkpointing)
    assert np.isfinite(res.best_val)
    assert res.job_results[res.best_job].adapter is not None
    assert res.best_val <= res.job_results["good"].best_val + 1e-9


def test_engine_api_listing1(env):
    cfg, ds, params = env
    engine = alto.Engine(strategy="adapter_parallel", total_gpus=4)
    tasks = [alto.Task(model=cfg, dataset=ds, num_gpus=2, max_steps=10,
                       num_slots=2,
                       search_space={"lr": [1e-3, 3e-3],
                                     "batch_size": [2]}),
             alto.Task(model=cfg, dataset=ds, num_gpus=1, max_steps=10,
                       num_slots=2, name="task-b",
                       search_space={"lr": [1e-3], "rank": [4, 8]})]
    schedule = engine.schedule(tasks, method="cp")
    schedule.validate(4)
    report = engine.batched_execution(
        tasks, schedule, alto.EarlyExit(warmup_ratio=0.2, select_ratio=0.5))
    assert len(report.task_results) == 2
    for tr in report.task_results.values():
        assert np.isfinite(tr.best_val)


def test_slot_batcher_homogeneous_and_epochs():
    ds = make_task_dataset("t", 64, seq_len=8, num_train=10, num_val=4)
    b = SlotBatcher(ds, Z=3, per_adapter_batch=4, seed=0)
    toks, labels = b.next_batch()
    assert toks.shape == (3, 4, 8) and labels.shape == (3, 4, 8)
    np.testing.assert_array_equal(toks[:, :, 1:], labels[:, :, :-1])
    for _ in range(10):
        b.next_batch()
    assert all(e >= 2 for e in b.epochs)        # cycled epochs
    vt, vl = b.val_batch()
    np.testing.assert_array_equal(vt[0], vt[1])  # same val rows per slot


def test_all_jobs_diverge_returns_empty_winner(env):
    """Every job diverging (all best_vals non-finite) must yield a
    TaskResult with best_job=None / best_val=inf, not a crash."""
    cfg, ds, params = env
    ex = BatchedExecutor(cfg, params, ds, Z=2, per_adapter_batch=4,
                         ee=EarlyExitConfig(warmup_ratio=0.2,
                                            select_ratio=1.0),
                         eval_every=2, seed=0)
    jobs = {
        "boom1": TrainConfig(learning_rate=1e9, lora_rank=8, max_steps=10,
                             grad_clip=0.0),
        "boom2": TrainConfig(learning_rate=5e9, lora_rank=8, max_steps=10,
                             grad_clip=0.0),
    }
    res = ex.run_task("task", jobs, total_steps=10)
    assert res.best_job is None
    assert res.best_val == float("inf")
    for r in res.job_results.values():
        assert r.exit_reason is not None
        assert r.adapter is None


def test_backfill_wired_through_intra_task_policy(env, monkeypatch):
    """§A.3 wiring: continue-phase backfill must go through the
    sched/intra_task ExecutorSlots policy (memory-model token-budget
    admission — the same-batch-size fast path is dead now that slots are
    ragged), not a FIFO queue pop."""
    from repro.sched import intra_task

    calls = []
    orig = intra_task.ExecutorSlots.backfill

    def spy(self, queue):
        calls.append([j.job_id for j in queue])
        return orig(self, queue)

    monkeypatch.setattr(intra_task.ExecutorSlots, "backfill", spy)
    cfg, ds, params = env
    ex = BatchedExecutor(cfg, params, ds, Z=2, per_adapter_batch=4,
                         ee=EarlyExitConfig(warmup_ratio=0.25,
                                            select_ratio=1.0),
                         eval_every=2, seed=0)
    jobs = {f"j{i}": TrainConfig(learning_rate=1e-3, lora_rank=4,
                                 max_steps=8) for i in range(4)}
    res = ex.run_task("task", jobs, total_steps=8)
    # 4 kept jobs on 2 slots: completions vacate slots that the policy
    # (not a FIFO pop) backfills
    assert calls, "backfill bypassed the intra-task policy"
    assert all(r.steps_trained >= 8 for r in res.job_results.values())
