"""Per-assigned-architecture smoke tests (reduced configs: 2 layers,
d_model<=512, <=4 experts): one forward + one train step + one serve step
on CPU, asserting output shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED, get_arch
from repro.core import lora as LORA
from repro.core import steps as STEPS
from repro.models import model as M
from repro.optim import adamw

Z, B, S = 2, 2, 32


def setup(arch):
    cfg = dataclasses.replace(get_arch(arch).reduced(), dtype="float32")
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    ranks = jnp.array([4, 8])
    lt = LORA.init_lora_tree(key, cfg, Z, ranks, M.target_shapes(cfg))
    tokens = jax.random.randint(key, (Z, B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.input_mode == "mixed":
        batch["modal_embeds"] = 0.02 * jax.random.normal(
            key, (Z, B, cfg.num_modality_tokens, cfg.d_model))
    return cfg, params, lt, ranks, batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_and_finite(arch):
    cfg, params, lt, ranks, batch = setup(arch)
    h, aux, _ = M.forward(cfg, params, lt, batch["tokens"],
                          modal_embeds=batch.get("modal_embeds"))
    assert h.shape == (Z, B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h)))
    loss, cnt = M.per_slot_xent(cfg, params, h, batch["labels"])
    assert loss.shape == (Z,) and bool(jnp.all(jnp.isfinite(loss)))
    assert float(cnt[0]) == B * S


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step(arch):
    cfg, params, lt, ranks, batch = setup(arch)
    opt = adamw.init_state(lt, Z)
    hp = adamw.SlotHParams.broadcast(Z, lr=1e-3)
    active = jnp.ones((Z,), jnp.int32)
    step = jax.jit(STEPS.make_train_step(cfg))
    lt2, opt2, metrics = step(params, lt, opt, hp, active, ranks, batch)
    assert bool(jnp.all(jnp.isfinite(metrics["per_slot_loss"])))
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda acc, x: acc + float(jnp.abs(x).sum()),
        jax.tree_util.tree_map(lambda a, b: a - b, lt2, lt), 0.0)
    assert moved > 0.0
    # rank masking is preserved after the update
    for t, ab in lt2.items():
        r = cfg.lora.r_max
        for z, rk in enumerate([4, 8]):
            if rk >= r:
                continue   # full-rank slot: no padded region
            assert float(jnp.abs(ab["A"][:, z, :, rk:]).max()) == 0.0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_serve_step(arch):
    cfg, params, lt, ranks, batch = setup(arch)
    serve = jax.jit(STEPS.make_serve_step(cfg))
    cache = M.init_cache(cfg, Z, B, 64)
    logits, cache2 = serve(params, lt, cache, batch["tokens"][:, :, 0])
    assert logits.shape == (Z, B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ["stablelm-3b", "rwkv6-3b", "hymba-1.5b"])
def test_ring_or_recurrent_long_decode(arch):
    """long_500k path: ring cache (dense/window) or pure state (ssm)."""
    cfg, params, lt, ranks, batch = setup(arch)
    ring = cfg.family != "ssm"
    cache = M.init_cache(cfg, Z, B, 128, ring=ring)
    serve = jax.jit(STEPS.make_serve_step(cfg))
    logits = None
    for t in range(4):
        logits, cache = serve(params, lt, cache, batch["tokens"][:, :, t])
    assert bool(jnp.all(jnp.isfinite(logits)))
    if ring:
        assert cache["layers"]["attn"]["k"].shape[3] == cfg.sliding_window
