"""Early-exit pattern detection (paper §5, Algorithm 1) on synthetic curves
+ hypothesis property tests on detector invariants."""
import math

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.early_exit import (EarlyExitConfig, ExitReason, JobMonitor,
                                   linreg_slope, warmup_select)

CFG = EarlyExitConfig(window=2, patience_div=2, patience_ovf=2,
                      tau_gap=0.1, tau_slope=0.001)


def drive(mon, train_curve, val_curve, evals_every=1):
    """Feed curves; return first decision."""
    step = 0
    for t, v in zip(train_curve, val_curve):
        mon.observe_train(t)
        step += 1
        d = mon.observe_val(v, step)
        if d is not None:
            return d
    return None


def test_divergence_detected():
    mon = JobMonitor(CFG, "j")
    up = list(np.linspace(2.0, 8.0, 12))
    d = drive(mon, up, up)
    assert d is not None and d.reason == ExitReason.DIVERGING


def test_healthy_run_not_exited():
    mon = JobMonitor(CFG, "j")
    down = list(np.linspace(3.0, 1.0, 30))
    d = drive(mon, down, [x + 0.05 for x in down])
    assert d is None


def test_overfitting_detected_and_checkpoints_best():
    mon = JobMonitor(CFG, "j")
    train = list(np.linspace(3.0, 0.5, 25))
    # val follows then turns up hard
    val = list(np.linspace(3.0, 1.8, 10)) + list(np.linspace(1.8, 3.2, 15))
    d = drive(mon, train, val)
    assert d is not None and d.reason == ExitReason.OVERFITTING
    assert math.isclose(d.best_val, min(val[:d.step]), rel_tol=1e-9)
    assert d.best_val_step == int(np.argmin(val[:d.step])) + 1


def test_patience_resets_on_transient_spike():
    cfg = EarlyExitConfig(window=2, patience_div=3, tau_slope=0.001,
                          tau_gap=10.0)   # disable overfit path
    mon = JobMonitor(cfg, "j")
    # two rising evals, then a drop (resets), then two rising: never 3 in a row
    train = [2.0, 2.2, 2.4, 1.8, 2.0, 2.2, 1.8, 2.0, 2.2, 1.8]
    d = drive(mon, train, train)
    assert d is None


def test_nan_loss_exits_immediately():
    mon = JobMonitor(CFG, "j")
    mon.observe_train(float("nan"))
    d = mon.observe_val(float("nan"), 1)
    assert d is not None and d.reason == ExitReason.DIVERGING


def test_warmup_select_keeps_top_quartile():
    cfg = EarlyExitConfig(select_ratio=0.25)
    monitors = {}
    for i in range(16):
        m = JobMonitor(cfg, f"j{i}")
        m.observe_train(3.0)
        m.observe_val(1.0 + 0.1 * i, 1)
        monitors[f"j{i}"] = m
    kept, dropped = warmup_select(monitors, cfg, num_candidates=16)
    assert kept == ["j0", "j1", "j2", "j3"]
    assert len(dropped) == 12


def test_warmup_select_ignores_already_exited():
    cfg = EarlyExitConfig(select_ratio=0.5)
    monitors = {}
    for i in range(4):
        m = JobMonitor(cfg, f"j{i}")
        m.observe_train(3.0)
        m.observe_val(1.0 + i, 1)
        monitors[f"j{i}"] = m
    monitors["j0"]._exit(ExitReason.DIVERGING, 1)
    kept, dropped = warmup_select(monitors, cfg, num_candidates=4)
    assert "j0" not in kept and "j0" not in dropped
    assert kept == ["j1", "j2"]


def test_linreg_slope():
    assert math.isclose(linreg_slope([0, 1, 2, 3]), 1.0)
    assert math.isclose(linreg_slope([3, 2, 1, 0]), -1.0)
    assert linreg_slope([5.0]) == 0.0


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=50)
@given(start=st.floats(0.5, 5.0), slope=st.floats(0.05, 1.0),
       n=st.integers(6, 40))
def test_property_monotone_rise_always_exits(start, slope, n):
    """Any strictly rising train+val trajectory longer than
    window+patience must trigger a divergence exit."""
    mon = JobMonitor(CFG, "j")
    curve = [start + slope * i for i in range(n)]
    d = drive(mon, curve, curve)
    assert d is not None and d.reason == ExitReason.DIVERGING
    assert d.step <= CFG.window + CFG.patience_div + 1


@settings(deadline=None, max_examples=50)
@given(start=st.floats(1.0, 5.0), slope=st.floats(0.01, 0.2),
       n=st.integers(10, 60))
def test_property_monotone_fall_never_exits(start, slope, n):
    mon = JobMonitor(CFG, "j")
    curve = [max(start - slope * i, 0.01) for i in range(n)]
    d = drive(mon, curve, curve)
    assert d is None


@settings(deadline=None, max_examples=30)
@given(vals=st.lists(st.floats(0.1, 10.0), min_size=4, max_size=32),
       ratio=st.sampled_from([0.1, 0.25, 0.5, 1.0]))
def test_property_topk_size_and_ordering(vals, ratio):
    cfg = EarlyExitConfig(select_ratio=ratio)
    monitors = {}
    for i, v in enumerate(vals):
        m = JobMonitor(cfg, f"j{i}")
        m.observe_train(v)
        m.observe_val(v, 1)
        monitors[f"j{i}"] = m
    kept, dropped = warmup_select(monitors, cfg, num_candidates=len(vals))
    k = max(int(math.ceil(ratio * len(vals))), 1)
    assert len(kept) == min(k, len(vals))
    if kept and dropped:
        worst_kept = max(monitors[j].val_hist[-1] for j in kept)
        best_dropped = min(monitors[j].val_hist[-1] for j in dropped)
        assert worst_kept <= best_dropped + 1e-12
