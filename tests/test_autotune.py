"""Tile-plan autotuner: candidate legality, tuned-vs-default bitwise
identity (fwd + VJP through the ops dispatch), winner persistence through
ProfileStore (including the atomic save round-trip)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.grouped_lora import autotune as AT
from repro.kernels.grouped_lora import ops
from repro.sched.profiler import ProfileStore

Z, T, DIN, DOUT, RMAX = 3, 24, 64, 48, 16


def _operands(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (Z, T, DIN), jnp.float32)
    A = 0.1 * jax.random.normal(ks[1], (Z, DIN, RMAX), jnp.float32)
    B = 0.1 * jax.random.normal(ks[2], (Z, RMAX, DOUT), jnp.float32)
    dy = jax.random.normal(ks[3], (Z, T, DOUT), jnp.float32)
    scale = jnp.ones((Z,), jnp.float32)
    ranks = jnp.asarray([8, 16, 8], jnp.int32)
    rows = jnp.asarray([T, T // 2, T], jnp.int32)
    return x, A, B, dy, scale, ranks, rows


# ---------------------------------------------------------------------------
# candidate legality
# ---------------------------------------------------------------------------

def test_candidates_are_sublane_mxu_legal():
    Tp, dinp, doutp, rp = AT.padded_dims(T, DIN, DOUT, RMAX)
    plans = AT.candidate_plans(T, DIN, DOUT, RMAX, max_candidates=64)
    assert plans[0] == AT.DEFAULT_PLAN
    assert len(plans) > 1, "no non-default candidates for this shape"
    for p in plans[1:]:
        assert AT.is_legal(p, T, DIN, DOUT, RMAX), p
        # sublane units on token/rank axes
        assert p.bm % 8 == 0 and p.bt % 8 == 0 and p.br % 8 == 0, p
        # grid-exact: a block below a dim it tiles must divide it
        for block, dim in ((p.bm, Tp), (p.bt, Tp), (p.br, rp),
                           (p.bn, dinp), (p.bn, doutp),
                           (p.bk, dinp), (p.bk, doutp)):
            assert block >= dim or dim % block == 0, (p, block, dim)


def test_candidates_pin_contraction_blocks():
    # bitwise contract: bk/bt tile contraction dims, so candidates must
    # keep the default grouping (see autotune module docstring)
    for p in AT.candidate_plans(T, DIN, DOUT, RMAX, max_candidates=64):
        assert p.bk == AT.DEFAULT_PLAN.bk and p.bt == AT.DEFAULT_PLAN.bt, p


def test_illegal_plans_rejected():
    bad = [AT.TilePlan(bm=12),                 # not a sublane multiple
           AT.TilePlan(br=4),                  # not a sublane multiple
           AT.TilePlan(bm=0),                  # non-positive
           AT.TilePlan(bm=16)]                 # 16 < Tp=24 and 24 % 16 != 0
    for p in bad:
        assert not AT.is_legal(p, T, DIN, DOUT, RMAX), p


def test_token_bucket_shares_plans_across_nearby_widths():
    assert AT.token_bucket(100) == AT.token_bucket(128) == 128
    assert AT.plan_key(DIN, DOUT, RMAX, Z, 100) == \
        AT.plan_key(DIN, DOUT, RMAX, Z, 128)
    assert AT.plan_key(DIN, DOUT, RMAX, Z, 129) != \
        AT.plan_key(DIN, DOUT, RMAX, Z, 128)


# ---------------------------------------------------------------------------
# tuned-vs-default bitwise identity (fwd + VJP)
# ---------------------------------------------------------------------------

def _fwd_vjp(plan):
    x, A, B, dy, scale, ranks, rows = _operands()

    def loss(x_, A_, B_):
        y = ops.ranklocal_grouped_lora(x_, A_, B_, scale, ranks, rows,
                                       interpret=True, plan=plan)
        return jnp.sum(y * dy), y

    (_, y), grads = jax.value_and_grad(loss, argnums=(0, 1, 2),
                                       has_aux=True)(x, A, B)
    return [np.asarray(y)] + [np.asarray(g) for g in grads]


def test_tuned_plan_bitwise_identical_fwd_and_vjp():
    tuned = [p for p in AT.candidate_plans(T, DIN, DOUT, RMAX,
                                           max_candidates=64)
             if p != AT.DEFAULT_PLAN]
    assert tuned, "shape produced no tuned candidates"
    base = _fwd_vjp(None)
    for plan in tuned[:4]:
        outs = _fwd_vjp(plan)
        for o, b in zip(outs, base):
            assert o.tobytes() == b.tobytes(), plan


def test_six_kernel_step_bitwise_across_candidates():
    # the sweep's own unit of comparison: all six rank-local kernels
    x, A, B, dy, scale, ranks, rows = _operands()
    args = (x, A, B, dy, scale, rows, ranks)
    base = [np.asarray(o) for o in
            AT.six_kernel_step(AT.DEFAULT_PLAN, interpret=True)(*args)]
    for plan in AT.candidate_plans(T, DIN, DOUT, RMAX,
                                   max_candidates=6)[1:]:
        outs = [np.asarray(o) for o in
                AT.six_kernel_step(plan, interpret=True)(*args)]
        for o, b in zip(outs, base):
            assert o.tobytes() == b.tobytes(), plan


def test_plan_threads_through_dense_and_ragged_dispatch():
    # full-rank dispatch routes to the dense/ragged paths — a tuned plan
    # must stay bitwise there too
    x, A, B, dy, scale, _, rows = _operands()
    full = jnp.full((Z,), RMAX, jnp.int32)
    plan = AT.TilePlan(bm=8, bn=128)
    for rows_arg in (None, rows):
        y0 = ops.ranklocal_grouped_lora(x, A, B, scale, full, rows_arg,
                                        interpret=True)
        y1 = ops.ranklocal_grouped_lora(x, A, B, scale, full, rows_arg,
                                        interpret=True, plan=plan)
        assert np.asarray(y0).tobytes() == np.asarray(y1).tobytes()


# ---------------------------------------------------------------------------
# sweep + winner persistence
# ---------------------------------------------------------------------------

def _tiny_sweep(**kw):
    return AT.sweep(DIN, DOUT, RMAX, Z=Z, tokens=T, interpret=True,
                    max_candidates=3, iters=1, repeats=1, **kw)


def test_sweep_winner_is_bitwise_and_not_slower_than_default():
    res = _tiny_sweep()
    assert res.best_s <= res.default_s + 1e-12
    winner = [c for c in res.candidates if c.plan == res.plan]
    assert winner and winner[0].bitwise_equal_default
    assert res.speedup >= 1.0
    assert res.flops > 0


def test_autotune_in_process_cache():
    AT.clear_plan_cache()
    p1 = AT.autotune_tile_plan(DIN, DOUT, RMAX, Z=Z, tokens=T,
                               interpret=True, max_candidates=3,
                               iters=1, repeats=1)
    assert AT.plan_key(DIN, DOUT, RMAX, Z, T) in AT._PLANS
    p2 = AT.autotune_tile_plan(DIN, DOUT, RMAX, Z=Z, tokens=T,
                               interpret=True)   # cache hit: no sweep args
    assert p1 == p2
    AT.clear_plan_cache()


def test_winner_persists_and_reloads_through_profile_store(tmp_path):
    store = ProfileStore()
    AT.clear_plan_cache()
    p1 = AT.autotune_tile_plan(DIN, DOUT, RMAX, Z=Z, tokens=T,
                               interpret=True, store=store,
                               max_candidates=3, iters=1, repeats=1)
    key = AT.plan_key(DIN, DOUT, RMAX, Z, T)
    assert AT.TilePlan.from_json(store.get_spec(key)) == p1
    # durable specs survive version bumps (observations do not evict them)
    store.record(("arch", 1), realized_duration=1.0, estimated_duration=2.0)
    assert store.get_spec(key) is not None

    path = tmp_path / "profile.json"
    store.save(str(path))
    reloaded = ProfileStore.load(str(path))
    AT.clear_plan_cache()
    # a fresh process with the reloaded store must NOT re-sweep: the
    # durable spec is the winner (iters/repeats absent would make a
    # sweep visible as a different plan only by accident, so check the
    # spec layer directly too)
    assert AT.TilePlan.from_json(reloaded.get_spec(key)) == p1
    p2 = AT.autotune_tile_plan(DIN, DOUT, RMAX, Z=Z, tokens=T,
                               interpret=True, store=reloaded)
    assert p2 == p1
    AT.clear_plan_cache()


def test_profile_store_save_is_atomic(tmp_path):
    # tmp-file + os.replace: no partial file is ever visible at `path`,
    # and a pre-existing good file survives a crashed writer (simulated
    # by the tmp file of a dead pid lying around)
    store = ProfileStore()
    store.put_spec(("tile_plan", 1, 2), {"bm": 8}, durable=True)
    path = tmp_path / "p.json"
    store.save(str(path))
    with open(path) as f:
        assert json.load(f)["durable_specs"]
    leftover = tmp_path / "p.json.tmp.99999"
    leftover.write_text("{corrupt")
    store.save(str(path))                   # replaces atomically, ignores it
    assert ProfileStore.load(str(path)).get_spec(
        ("tile_plan", 1, 2)) == {"bm": 8}
    assert os.path.exists(leftover)          # untouched: distinct pid suffix


def test_durable_spec_must_be_json():
    store = ProfileStore()
    with pytest.raises(TypeError):
        store.put_spec(("k",), object(), durable=True)
