"""Flash-attention Pallas kernel vs naive oracle: shape/dtype/window sweeps
+ grad path (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops
from repro.kernels.flash_attention.ref import flash_attention_ref


def make(B, Sq, Sk, hd, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("B,Sq,Sk,hd,bq,bk", [
    (2, 64, 64, 32, 16, 16),
    (1, 128, 128, 64, 32, 64),
    (3, 32, 96, 16, 16, 32),      # Sq < Sk (suffix alignment)
])
@pytest.mark.parametrize("window", [0, 24])
def test_matches_oracle(B, Sq, Sk, hd, bq, bk, window):
    q, k, v = make(B, Sq, Sk, hd)
    got = ops.flash_attention(q, k, v, window=window, bq=bq, bk=bk,
                              interpret=True)
    want = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_noncausal():
    q, k, v = make(1, 32, 32, 16, seed=4)
    got = ops.flash_attention(q, k, v, causal=False, bq=16, bk=16,
                              interpret=True)
    want = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_bf16():
    q, k, v = make(2, 64, 64, 32, seed=5, dtype=jnp.bfloat16)
    got = ops.flash_attention(q, k, v, bq=32, bk=32, interpret=True)
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_block_size_invariance():
    q, k, v = make(1, 64, 64, 16, seed=6)
    outs = [ops.flash_attention(q, k, v, bq=b1, bk=b2, interpret=True)
            for b1, b2 in ((16, 16), (32, 64), (64, 32))]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


def test_grad_path():
    q, k, v = make(1, 32, 32, 16, seed=7)

    def loss_k(q, k, v):
        return jnp.sum(jnp.tanh(
            ops.flash_attention(q, k, v, bq=16, bk=16, interpret=True)))

    def loss_r(q, k, v):
        return jnp.sum(jnp.tanh(flash_attention_ref(q, k, v)))

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
