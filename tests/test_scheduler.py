"""Inter-task strip-packing solver + intra-task admission (paper §7)."""
import itertools

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.sched.events import ClusterSimulator
from repro.sched.inter_task import (TaskSpec, branch_and_bound, list_schedule,
                                    lower_bound, lpt_schedule, solve)
from repro.sched.intra_task import (IntraTaskScheduler, MemoryModel,
                                    PendingJob, fit_memory_model)


def brute_force_makespan(tasks, G):
    best = float("inf")
    for order in itertools.permutations(tasks):
        s = list_schedule(order, G)
        best = min(best, s.makespan)
    return best


def test_paper_figure5_shape():
    """SJF leaves the cluster idle; makespan-aware plan beats it."""
    tasks = [TaskSpec("short1", 1.0, 1), TaskSpec("short2", 1.0, 1),
             TaskSpec("long", 4.0, 2), TaskSpec("mid", 2.0, 2)]
    G = 2
    sjf = solve(tasks, G, "sjf")
    cp = solve(tasks, G, "cp")
    assert cp.makespan <= sjf.makespan
    assert cp.makespan == brute_force_makespan(tasks, G)


def test_validation_catches_overlap():
    s = solve([TaskSpec("a", 1.0, 2), TaskSpec("b", 2.0, 3),
               TaskSpec("c", 1.5, 1)], 4, "cp")
    s.validate(4)


def test_paper_scale_instance_under_a_second():
    """11 heterogeneous tasks on 8 GPUs (paper §8.2 inter-task setting)."""
    rng = np.random.default_rng(0)
    tasks = []
    for i, g in enumerate([4, 2, 2, 1, 1, 1, 1, 2, 4, 1, 1]):
        tasks.append(TaskSpec(f"t{i}", float(rng.uniform(1, 10)), g))
    s = solve(tasks, 8, "cp")
    s.validate(8)
    assert s.solve_time_s < 6.0
    assert s.makespan >= lower_bound(tasks, 8) - 1e-9
    assert s.makespan <= lpt_schedule(tasks, 8).makespan + 1e-9


@settings(deadline=None, max_examples=40)
@given(tasks_raw=st.lists(
    st.tuples(st.floats(0.5, 8.0), st.integers(1, 4)),
    min_size=1, max_size=6),
    G=st.sampled_from([4, 8]))
def test_property_bnb_matches_bruteforce(tasks_raw, G):
    tasks = [TaskSpec(f"t{i}", d, g) for i, (d, g) in enumerate(tasks_raw)]
    s = branch_and_bound(tasks, G)
    s.validate(G)
    bf = brute_force_makespan(tasks, G)
    assert abs(s.makespan - bf) < 1e-9
    assert s.makespan >= lower_bound(tasks, G) - 1e-9


@settings(deadline=None, max_examples=30)
@given(tasks_raw=st.lists(
    st.tuples(st.floats(0.5, 8.0), st.integers(1, 8)),
    min_size=1, max_size=12),
    G=st.sampled_from([8, 16]))
def test_property_schedules_always_valid(tasks_raw, G):
    tasks = [TaskSpec(f"t{i}", d, g) for i, (d, g) in enumerate(tasks_raw)]
    for method in ("cp", "lpt", "sjf"):
        s = solve(tasks, G, method)
        s.validate(G)
        assert s.makespan >= max(t.duration for t in tasks) - 1e-9


def test_event_driven_early_exit_reclaims_gpus():
    """A task finishing early (early exit) frees GPUs for pending work."""
    sim = ClusterSimulator(G=4, method="cp")
    sim.submit(TaskSpec("big", 10.0, 4), actual_duration=2.0)
    sim.submit(TaskSpec("next", 3.0, 4))
    mk = sim.run_until_idle()
    assert abs(mk - 5.0) < 1e-9     # 2 (early-exited) + 3
    assert sim.replans >= 2


def test_cluster_simulator_parallel_packing():
    sim = ClusterSimulator(G=4, method="cp")
    for i in range(4):
        sim.submit(TaskSpec(f"t{i}", 2.0, 2))
    mk = sim.run_until_idle()
    assert abs(mk - 4.0) < 1e-9     # two waves of two concurrent tasks


# ---------------------------------------------------------------------------
# intra-task
# ---------------------------------------------------------------------------

def test_memory_model_fit_recovers_linear():
    seq = 128
    k0, k1 = 3e9, 1e4
    pts = [(b, k0 + k1 * b * seq) for b in (1, 2, 4, 8, 16)]
    m = fit_memory_model(pts, seq, capacity=16e9)
    assert abs(m.k0 - k0) / k0 < 1e-6
    assert abs(m.k1 - k1) / k1 < 1e-6
    assert m.fits(4)
    assert not m.fits(10 ** 9)


def test_admission_greedy_decreasing_and_budget_backfill():
    mem = MemoryModel(k0=0, k1=1.0, seq_len=1, capacity=100,
                      safety_margin=1.0)
    sched = IntraTaskScheduler(mem, max_slots=8)
    queue = [PendingJob("a8", 8), PendingJob("b8", 8), PendingJob("c4", 4),
             PendingJob("d2", 2), PendingJob("e8", 8)]
    admitted = sched.admit_initial(queue)
    # decreasing order: all fit (8+8+8+4+2=30 <= 100)
    assert [j.per_adapter_batch for j in admitted] == [8, 8, 8, 4, 2]
    # backfill is pure memory-model budget (ragged slots: no same-batch
    # fast path): the LARGEST job that fits wins, width regardless
    sched.evict("a8")
    j = sched.backfill([PendingJob("x4", 4), PendingJob("y8", 8)])
    assert j.job_id == "y8"
    sched.evict("c4")
    j2 = sched.backfill([PendingJob("z2", 2)])
    assert j2.job_id == "z2"     # any width fits the budget => admitted


def test_backfill_budget_rejects_over_budget_width():
    """Ragged backfill: a pending job wider than the remaining token
    budget is skipped in favor of one that fits — the memory model is the
    only gate."""
    mem = MemoryModel(k0=0, k1=1.0, seq_len=1, capacity=10,
                      safety_margin=1.0)
    sched = IntraTaskScheduler(mem, max_slots=8)
    sched.admit_initial([PendingJob("a8", 8)])
    j = sched.backfill([PendingJob("w4", 4), PendingJob("n2", 2)])
    assert j.job_id == "n2"            # 8+4 > 10, 8+2 fits
    assert sched.backfill([PendingJob("w4", 4)]) is None


def test_admission_respects_memory_cap():
    mem = MemoryModel(k0=0, k1=1.0, seq_len=1, capacity=10,
                      safety_margin=1.0)
    sched = IntraTaskScheduler(mem, max_slots=8)
    queue = [PendingJob(f"j{i}", 4) for i in range(5)]
    admitted = sched.admit_initial(queue)
    assert len(admitted) == 2            # 4+4 <= 10, third would exceed
    assert sched.total_batch == 8
