"""MoE dispatch invariants: capacity, lossless small groups, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.moe import init_moe_params, moe_block, pick_group_size


def setup(E=4, k=2, d=32, ff=64, seed=0):
    moe = MoEConfig(num_experts=E, top_k=k, d_ff_expert=ff)
    params = init_moe_params(jax.random.PRNGKey(seed), d, moe, jnp.float32)
    return moe, params


def test_output_shape_and_finite():
    moe, params = setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 16, 32))
    out, aux = moe_block(x, params, moe)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0.0


def test_small_group_lossless_matches_dense_topk():
    """For s<=64 (lossless capacity), grouped dispatch == explicit top-k."""
    moe, params = setup(E=4, k=2)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 16, 32))
    out, _ = moe_block(x, params, moe)

    xt = x.reshape(-1, 32)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    want = jnp.zeros_like(xt)
    from repro.models.common import swiglu
    for e in range(4):
        h = swiglu(xt @ params["w_gate"][e], xt @ params["w_up"][e])
        ye = h @ params["w_down"][e]
        w = jnp.where(gi == e, gv, 0.0).sum(-1)
        want = want + ye * w[:, None]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 32)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


def test_shared_expert_added():
    moe = MoEConfig(num_experts=2, top_k=1, d_ff_expert=16,
                    num_shared_experts=1, d_ff_shared=16)
    params = init_moe_params(jax.random.PRNGKey(0), 8, moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 8, 8))
    out, _ = moe_block(x, params, moe)
    params2 = dict(params)
    params2["shared"] = jax.tree_util.tree_map(jnp.zeros_like,
                                               params["shared"])
    out2, _ = moe_block(x, params2, moe)
    assert float(jnp.abs(out - out2).max()) > 0.0


def test_pick_group_size():
    assert pick_group_size(1 << 20) <= 4096
    assert (1 << 20) % pick_group_size(1 << 20) == 0
    assert pick_group_size(128) == 128
    assert pick_group_size(1) == 1
    for T in (256, 640, 24576, 3 * 4096):
        assert T % pick_group_size(T) == 0


def test_capacity_drops_under_pressure():
    """With cf tiny and large groups, some second-choice tokens drop:
    combine weights per token sum to <= 1 and >= 0."""
    moe = MoEConfig(num_experts=2, top_k=2, d_ff_expert=8,
                    capacity_factor=0.5)
    params = init_moe_params(jax.random.PRNGKey(0), 8, moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 64, 8))  # s=128
    out, aux = moe_block(x, params, moe)
    assert bool(jnp.all(jnp.isfinite(out)))
