"""Minimal offline stand-in for the ``hypothesis`` package.

The property tests in this repo use a small, fixed subset of hypothesis:
``@settings(deadline=..., max_examples=...)``, ``@given(**strategies)`` and
the ``integers / floats / lists / tuples / sampled_from / booleans``
strategies. When the real package is installed (the ``[test]`` extra, as CI
does) it is used untouched; on bare containers conftest.py registers this
module as ``hypothesis`` so collection and execution still work.

The stand-in draws deterministic pseudo-random examples (seeded per test
name) with no shrinking — strictly weaker than hypothesis, strictly better
than 5 test files failing collection.
"""
from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

MAX_EXAMPLES_CAP = 20       # keep bare-container runs fast


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example_with(self, rng: np.random.Generator):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(seq) -> SearchStrategy:
    items = list(seq)
    return SearchStrategy(lambda rng: items[int(rng.integers(len(items)))])


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 10, **_kw) -> SearchStrategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example_with(rng) for _ in range(n)]
    return SearchStrategy(draw)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.example_with(rng) for s in strategies))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def settings(deadline=None, max_examples: int = 20, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **fixture_kwargs):
            declared = getattr(wrapper, "_stub_max_examples",
                               getattr(fn, "_stub_max_examples", 20))
            n = min(int(declared), MAX_EXAMPLES_CAP)
            seed0 = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = np.random.default_rng((seed0, i))
                drawn = {k: s.example_with(rng)
                         for k, s in sorted(strategies.items())}
                try:
                    fn(*args, **fixture_kwargs, **drawn)
                except Exception as err:
                    raise AssertionError(
                        f"stub-hypothesis falsified {fn.__qualname__} on "
                        f"example {i}: {drawn!r}") from err

        # hide the drawn parameters from pytest's fixture resolution
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strategies])
        del wrapper.__wrapped__
        return wrapper
    return deco


# expose as a module object so `from hypothesis import strategies as st`
# resolves through the registered package
strategies = types.ModuleType("hypothesis.strategies")
for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
              "tuples", "just"):
    setattr(strategies, _name, globals()[_name])
strategies.SearchStrategy = SearchStrategy
