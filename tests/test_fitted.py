"""Profile-fitted cost models: (k0, k1, k2) recovery from synthetic
observations, minimum-observation/degeneracy guards with analytic
fallback, spec-cache freshness, and the Engine/TuningService fitted=True
wiring."""
import numpy as np
import pytest

from repro.sched import fitted as F
from repro.sched.intra_task import MemoryModel
from repro.sched.profiler import (MAX_STEP_OBSERVATIONS, ProfileStore,
                                  StepObservation)

KEY = ("arch", 1)
K0, K1, K2 = 0.02, 3e-6, 5e-8


def _seed_store(n=32, noise=1e-5, seed=0, mem=True):
    rng = np.random.default_rng(seed)
    store = ProfileStore()
    for _ in range(n):
        t = float(rng.integers(256, 8192))
        r = float(rng.integers(4, 64))
        store.record_step(
            KEY, tokens=t, rank_tokens=t * r,
            wall_s=K0 + K1 * t + K2 * t * r + rng.normal(0.0, noise),
            peak_memory=(1e9 + 1e4 * t + 100.0 * t * r) if mem else None)
    return store


# ---------------------------------------------------------------------------
# fit recovery
# ---------------------------------------------------------------------------

def test_step_model_recovers_known_coefficients():
    m = F.fitted_step_model(_seed_store(), KEY)
    assert m is not None
    assert m.k0 == pytest.approx(K0, rel=0.05)
    assert m.k1 == pytest.approx(K1, rel=0.05)
    assert m.k2 == pytest.approx(K2, rel=0.05)
    assert m.rms_rel_error < 0.01
    assert m.observations == 32
    # slot interface == flat interface
    assert m.step_time([1000.0, 1000.0], [8.0, 16.0]) == pytest.approx(
        m.predict(2000.0, 1000.0 * 8 + 1000.0 * 16))


def test_memory_model_recovers_known_coefficients():
    frame = MemoryModel(k0=0.0, k1=0.0, seq_len=64, capacity=16 * 2 ** 30,
                        safety_margin=0.8, r_max=32)
    mm = F.fitted_memory_model(_seed_store(), KEY, frame)
    assert mm is not frame
    assert mm.k0 == pytest.approx(1e9, rel=0.05)
    assert mm.k1 == pytest.approx(1e4, rel=0.05)
    assert mm.k2 == pytest.approx(100.0, rel=0.05)
    # device facts come from the frame, not the fit
    assert (mm.capacity, mm.safety_margin, mm.seq_len, mm.r_max) == \
        (frame.capacity, frame.safety_margin, frame.seq_len, frame.r_max)


def test_nonnegative_clamp_preserves_safety_direction():
    # rank term anti-correlated with wall time => OLS would fit k2 < 0
    # ("more rank is free"); the column-drop refit must zero it instead
    rng = np.random.default_rng(1)
    obs = []
    for _ in range(24):
        t = float(rng.integers(256, 8192))
        r = float(rng.integers(4, 64))
        obs.append(StepObservation(tokens=t, rank_tokens=t * r,
                                   wall_s=0.01 + K1 * t - 1e-9 * t * r))
    m = F.fit_step_model(obs)
    assert m is not None and m.k2 == 0.0 and m.k1 > 0


# ---------------------------------------------------------------------------
# guards + fallback
# ---------------------------------------------------------------------------

def test_min_observations_guard():
    store = _seed_store(n=F.MIN_OBSERVATIONS - 1)
    assert F.fitted_step_model(store, KEY) is None
    frame = MemoryModel(k0=1.0, k1=1.0, seq_len=64, capacity=1e9)
    assert F.fitted_memory_model(store, KEY, frame) is frame  # fallback


def test_degenerate_design_falls_back():
    # every step at one rank: rank_tokens is collinear with tokens, the
    # fit cannot separate k1 from k2 — analytic must win
    store = ProfileStore()
    for i in range(20):
        t = 100.0 * (i + 1)
        store.record_step(KEY, tokens=t, rank_tokens=8 * t,
                          wall_s=0.01 + 1e-5 * t)
    assert F.fitted_step_model(store, KEY) is None


def test_fitted_fused_step_time_fallback_matches_analytic():
    from repro.configs.registry import get_arch
    from repro.sched import profiler
    cfg = get_arch("paper-llama-tiny")
    analytic = profiler.fused_step_time(cfg, [512.0] * 2, [8.0, 16.0], 1)
    # no store/key -> analytic; empty store -> analytic
    assert F.fitted_fused_step_time(cfg, [512.0] * 2, [8.0, 16.0], 1) == \
        pytest.approx(analytic)
    assert F.fitted_fused_step_time(cfg, [512.0] * 2, [8.0, 16.0], 1,
                                    store=ProfileStore(), key=KEY) == \
        pytest.approx(analytic)
    # seeded store -> the fitted prediction, not the roofline
    m = F.fitted_step_model(store := _seed_store(), KEY)
    assert F.fitted_fused_step_time(cfg, [512.0] * 2, [8.0, 16.0], 1,
                                    store=store, key=KEY) == \
        pytest.approx(m.step_time([512.0] * 2, [8.0, 16.0]))


def test_spec_cache_invalidation_on_new_observation():
    store = _seed_store()
    m1 = F.fitted_step_model(store, KEY)
    assert F.fitted_step_model(store, KEY) is m1          # cached
    store.record_step(KEY, tokens=100.0, rank_tokens=800.0, wall_s=0.05)
    m2 = F.fitted_step_model(store, KEY)
    assert m2 is not m1                                    # re-derived


def test_observation_cap_fifo():
    store = ProfileStore()
    for i in range(MAX_STEP_OBSERVATIONS + 10):
        store.record_step(KEY, tokens=float(i), rank_tokens=0.0, wall_s=1.0)
    obs = store.step_observations(KEY)
    assert len(obs) == MAX_STEP_OBSERVATIONS
    assert obs[0].tokens == 10.0                           # oldest evicted


def test_observations_persist_through_save_load(tmp_path):
    store = _seed_store(n=10)
    path = tmp_path / "p.json"
    store.save(str(path))
    reloaded = ProfileStore.load(str(path))
    assert reloaded.step_observations(KEY) == store.step_observations(KEY)
    assert F.fitted_step_model(reloaded, KEY) is not None


# ---------------------------------------------------------------------------
# Engine / TuningService wiring
# ---------------------------------------------------------------------------

def _tiny_task():
    from repro.core.engine import Task
    return Task(model="paper-llama-tiny", dataset="fit-wire",
                search_space={"lr": [1e-3], "rank": [4]}, max_steps=4)


def test_engine_fitted_flag_swaps_memory_model():
    from repro.core.engine import Engine
    task = _tiny_task()
    plain = Engine(fitted=False)
    base = plain.memory_model(task)
    eng = Engine(fitted=True)
    # below the observation guard: analytic coefficients, r_max framed in
    mem = eng.memory_model(task)
    assert (mem.k0, mem.k1) == (base.k0, base.k1)
    assert mem.r_max == task.model_config().lora.r_max
    # seed enough observations: the fitted coefficients take over
    key = eng.profile_key(task)
    rng = np.random.default_rng(0)
    for _ in range(F.MIN_OBSERVATIONS + 4):
        t = float(rng.integers(256, 8192))
        r = float(rng.integers(4, 32))
        eng.profile_store.record_step(key, tokens=t, rank_tokens=t * r,
                                      wall_s=0.01,
                                      peak_memory=1e9 + 1e4 * t + 50 * t * r)
    fitted_mem = eng.memory_model(task)
    assert fitted_mem.k0 == pytest.approx(1e9, rel=0.05)
    assert fitted_mem.k2 == pytest.approx(50.0, rel=0.05)
    # the default engine is untouched by the same data
    plain.profile_store = eng.profile_store
    assert plain.memory_model(task).k0 == base.k0


def test_service_records_step_observations_and_fitted_conflict():
    from repro.core.engine import Engine
    from repro.core.service import TuningService
    svc = TuningService(total_gpus=2, fitted=True)
    assert svc.engine.fitted is True
    task = _tiny_task()
    h = svc.submit(task)
    h.result()
    key = svc.engine.profile_key(task)
    assert svc.profile_store.step_observation_count(key) >= 1
    obs = svc.profile_store.step_observations(key)[0]
    assert obs.tokens > 0 and obs.wall_s > 0
    assert obs.rank_tokens >= obs.tokens          # rank >= 1 charged
    with pytest.raises(ValueError):
        TuningService(engine=Engine(fitted=False), fitted=True)
