"""Durable crash recovery (paper §4 service hardening): write-ahead event
journal round-trips, in-flight SlotSnapshot checkpoints with bitwise
resume, chaos fault injection (elastic <= static survives it), and
graceful degradation on corrupt durable state."""
import glob
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint.checkpoint import load_state_tree, save_state_tree
from repro.checkpoint.taskstate import (SimulatedCrash, TaskCheckpointer,
                                        load_task_checkpoint)
from repro.core.early_exit import EarlyExitConfig
from repro.core.service import TuningService
from repro.sched.chaos import Fault, FaultPlan, FaultyTaskDriver, chaos_spec
from repro.sched.cluster import (ElasticClusterRuntime, SimulatedTaskDriver,
                                 execute_static, sim_task_spec)
from repro.sched.events import (EventKind, ProgressEvent, event_from_json,
                                event_to_json)
from repro.sched.journal import EventJournal, replay_journal
from repro.sched.inter_task import solve

CHUNK_STEPS = 5      # SimulatedTaskDriver default


# ---------------------------------------------------------------------------
# journal: append / rotate / replay
# ---------------------------------------------------------------------------

def test_journal_roundtrip_with_rotation(tmp_path):
    sd = str(tmp_path / "state")
    j = EventJournal(sd, rotate_every=3)
    recs = [{"rec": "session", "total_gpus": 4},
            {"rec": "submit", "name": "a", "kind": "driver",
             "spec": {"name": "a", "duration": 1.0, "gpus": 1,
                      "release": 0.0}},
            {"rec": "submit", "name": "b", "kind": "driver",
             "spec": {"name": "b", "duration": 2.0, "gpus": 2,
                      "release": 0.0}},
            {"rec": "ckpt", "task": "a", "path": "/x/1.npz", "chunk": 1,
             "remaining_steps_bound": 10},
            {"rec": "ckpt", "task": "a", "path": "/x/2.npz", "chunk": 2,
             "remaining_steps_bound": 5},
            {"rec": "event", "event": event_to_json(ProgressEvent(
                kind=EventKind.TASK_COMPLETED, task="b", time=3.0))},
            {"rec": "serve", "task": "b", "path": "/s/b.npz"}]
    for r in recs:
        j.append(r)
    j.close()
    # rotation sealed full segments; the tail stays in current.jsonl
    assert len(glob.glob(os.path.join(sd, "journal",
                                      "segment-*.jsonl"))) == 2
    rep = replay_journal(sd)
    assert not rep.corrupt and not rep.torn_tail
    assert rep.session()["total_gpus"] == 4
    assert sorted(r["name"] for r in rep.submits()) == ["a", "b"]
    assert rep.terminal_tasks() == {"b"}
    assert rep.checkpoints()["a"]["chunk"] == 2      # latest wins
    assert rep.serves() == {"b": "/s/b.npz"}

    # a new journal over the same dir keeps appending, not clobbering
    j2 = EventJournal(sd, rotate_every=3)
    j2.append({"rec": "event", "event": event_to_json(ProgressEvent(
        kind=EventKind.TASK_CANCELLED, task="a", time=4.0))})
    j2.close()
    assert replay_journal(sd).terminal_tasks() == {"a", "b"}


def test_journal_torn_tail_tolerated(tmp_path):
    sd = str(tmp_path / "state")
    j = EventJournal(sd)
    j.append({"rec": "submit", "name": "a", "kind": "driver", "spec": {}})
    j.append({"rec": "submit", "name": "b", "kind": "driver", "spec": {}})
    j.close()
    cur = os.path.join(sd, "journal", "current.jsonl")
    with open(cur, "a") as f:
        f.write('{"rec": "submit", "name": "c"')   # crash mid-append
    rep = replay_journal(sd)
    # a torn final line is the expected crash signature, not corruption
    assert rep.torn_tail and not rep.corrupt
    assert sorted(r["name"] for r in rep.submits()) == ["a", "b"]


def test_journal_corrupt_segment_flagged(tmp_path):
    sd = str(tmp_path / "state")
    j = EventJournal(sd)
    for n in ("a", "b", "c"):
        j.append({"rec": "submit", "name": n, "kind": "driver", "spec": {}})
    j.close()
    cur = os.path.join(sd, "journal", "current.jsonl")
    lines = open(cur).read().splitlines()
    lines[1] = lines[1][: len(lines[1]) // 2]       # mid-file truncation
    with open(cur, "w") as f:
        f.write("\n".join(lines) + "\n")
    rep = replay_journal(sd)
    assert rep.corrupt                               # not a torn tail
    assert "a" in {r["name"] for r in rep.submits()}  # prefix still usable


def test_event_json_roundtrip():
    e = ProgressEvent(kind=EventKind.POD_KILLED, task="t0", time=1.5,
                      job="t0/j", reason="injected", step=7,
                      dropped=("a", "b"), detail="backoff=0.3")
    d = json.loads(json.dumps(event_to_json(e)))
    assert event_from_json(d) == e


def test_state_tree_roundtrip(tmp_path):
    path = str(tmp_path / "st.npz")
    tree = {"snap": {"task/a": {"A": np.arange(6, dtype=np.float32),
                                "B": np.ones((2, 3), np.int64)}},
            "prng": np.asarray([1, 2], np.uint32)}
    meta = {"chunk": 3, "queue": ["x", "y"]}
    save_state_tree(path, tree, meta=meta)
    tree2, meta2 = load_state_tree(path)
    assert meta2["chunk"] == 3 and meta2["queue"] == ["x", "y"]
    assert list(tree2) == list(tree)                 # order preserved
    np.testing.assert_array_equal(tree2["snap"]["task/a"]["A"],
                                  tree["snap"]["task/a"]["A"])
    np.testing.assert_array_equal(tree2["snap"]["task/a"]["B"],
                                  tree["snap"]["task/a"]["B"])
    np.testing.assert_array_equal(tree2["prng"], tree["prng"])


# ---------------------------------------------------------------------------
# kill-and-recover end to end on the real tiny engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_env():
    from repro.data.synthetic import make_task_dataset
    from tests.conftest import reduced_f32
    cfg = reduced_f32("paper-llama-tiny", num_layers=2, d_model=128,
                      vocab=256)
    ds = make_task_dataset("rec", cfg.vocab_size, seq_len=32, num_train=64,
                           num_val=16, difficulty=0.2)
    return cfg, ds


EE = EarlyExitConfig(warmup_ratio=0.2, select_ratio=0.5)


def _mk_task(tiny_env):
    """Ragged widths (batch_size 2 vs 4), mixed TRUE ranks (4 vs 8), and
    more jobs than slots — so the crash lands mid-rotation with live
    PRNG streams and per-slot hyperparameters to restore."""
    from repro.core import engine as alto
    cfg, ds = tiny_env
    return alto.Task(model=cfg, dataset=ds, num_gpus=2, max_steps=10,
                     num_slots=2, name="tenant-r",
                     search_space={"lr": [1e-3, 3e-3], "rank": [4, 8],
                                   "batch_size": [2, 4]})


@pytest.fixture(scope="module")
def baseline(tiny_env):
    """Uninterrupted reference run, shared across the recovery tests."""
    svc = TuningService(total_gpus=4, eval_every=2)
    res = svc.submit(_mk_task(tiny_env), early_exit=EE).result()
    return res, svc._meta["tenant-r"].driver._steps


def _crash_run(tiny_env, tmp_path, fail_after):
    sd = str(tmp_path / "state")
    svc = TuningService(total_gpus=4, eval_every=2, state_dir=sd,
                        ckpt_every=1)
    svc._ckpt.fail_after["*"] = fail_after
    h = svc.submit(_mk_task(tiny_env), early_exit=EE)
    with pytest.raises(SimulatedCrash):
        h.result()
    return sd


def test_kill_and_recover_bitwise(tiny_env, baseline, tmp_path):
    res0, steps0 = baseline
    sd = _crash_run(tiny_env, tmp_path, fail_after=3)
    svc = TuningService.recover(sd, tasks=[(_mk_task(tiny_env), EE)])
    rep = svc.run_until_idle()
    res = rep.task_results["tenant-r"]
    # bitwise: same winner, bit-identical best validation loss
    assert res.best_job == res0.best_job
    assert float(res.best_val) == float(res0.best_val)
    # the resumed run recomputed strictly less than a from-zero restart
    assert svc._meta["tenant-r"].driver._steps < steps0
    recov = [e for e in rep.events if e.kind is EventKind.TASK_RECOVERED]
    assert len(recov) == 1 and recov[0].reason == "resumed"


def test_corrupt_checkpoint_degrades_to_requeue(tiny_env, baseline,
                                                tmp_path):
    res0, steps0 = baseline
    sd = _crash_run(tiny_env, tmp_path, fail_after=2)
    for p in glob.glob(os.path.join(sd, "ckpt", "*", "*.npz")):
        with open(p, "wb") as f:
            f.write(b"\x00" * 100)                   # trash every snapshot
    svc = TuningService.recover(sd, tasks=[(_mk_task(tiny_env), EE)])
    rep = svc.run_until_idle()
    res = rep.task_results["tenant-r"]
    # degraded but correct: full re-run from step 0, same final answer
    assert res.best_job == res0.best_job
    assert float(res.best_val) == float(res0.best_val)
    assert svc._meta["tenant-r"].driver._steps == steps0
    recov = [e for e in rep.events if e.kind is EventKind.TASK_RECOVERED]
    assert len(recov) == 1 and recov[0].reason == "requeued"


def test_corrupt_journal_distrusts_snapshots(tiny_env, baseline, tmp_path):
    res0, steps0 = baseline
    sd = _crash_run(tiny_env, tmp_path, fail_after=2)
    cur = os.path.join(sd, "journal", "current.jsonl")
    lines = open(cur).read().splitlines()
    assert len(lines) > 3
    lines[2] = "{garbage"                            # mid-file corruption
    with open(cur, "w") as f:
        f.write("\n".join(lines) + "\n")
    svc = TuningService.recover(sd, tasks=[(_mk_task(tiny_env), EE)])
    rep = svc.run_until_idle()
    res = rep.task_results["tenant-r"]
    assert res.best_job == res0.best_job
    assert float(res.best_val) == float(res0.best_val)
    assert svc._meta["tenant-r"].driver._steps == steps0   # from zero


def test_checkpointer_prunes_and_latest(tmp_path):
    ck = TaskCheckpointer(str(tmp_path / "s"), every=1, keep=2)
    tdir = os.path.join(ck.dir, "t")
    os.makedirs(tdir)
    for i in (1, 2, 3):
        save_state_tree(os.path.join(tdir, f"chunk-{i:06d}.npz"),
                        {"x": np.zeros(1)}, meta={"chunk": i, "schema": 1})
        ck._prune(tdir)
    left = sorted(os.listdir(tdir))
    assert left == ["chunk-000002.npz", "chunk-000003.npz"]
    assert ck.latest("t").endswith("chunk-000003.npz")
    assert load_task_checkpoint(ck.latest("t"))[1]["chunk"] == 3
    # unreadable artifact -> None, never an exception
    with open(ck.latest("t"), "wb") as f:
        f.write(b"nope")
    assert load_task_checkpoint(ck.latest("t")) is None


# ---------------------------------------------------------------------------
# chaos harness
# ---------------------------------------------------------------------------

def _chaos_workload(rng, G, plan_faults):
    tasks = []
    n = int(rng.integers(2, 6))
    for i in range(n):
        name = f"t{i}"
        K = int(rng.integers(2, 16))
        Z = int(rng.integers(1, 5))
        total = int(rng.integers(10, 120))
        warm = int(rng.integers(1, max(total // 4, 2)))
        step_time = float(rng.uniform(0.005, 0.05))
        gpus = int(rng.integers(1, G + 1))
        chunk_bound = CHUNK_STEPS * step_time
        work = total * step_time
        if rng.random() < 0.7:
            faults = tuple(
                Fault(at_progress=float(rng.uniform(0.0, work)),
                      backoff=float(rng.uniform(0.0, 0.5)))
                for _ in range(int(rng.integers(1, 4))))
            plan_faults.faults[name] = faults
        faults = plan_faults.for_task(name)
        spec = chaos_spec(
            sim_task_spec(name, K=K, Z=Z, total_steps=total,
                          warmup_steps=warm, step_time_s=step_time,
                          gpus=gpus),
            faults, chunk_bound)

        def factory(name=name, K=K, Z=Z, total=total, warm=warm,
                    step_time=step_time, faults=faults, cb=chunk_bound):
            inner = SimulatedTaskDriver(name, K=K, Z=Z, total_steps=total,
                                        warmup_steps=warm,
                                        step_time_s=step_time)
            return FaultyTaskDriver(name, inner, faults, cb)
        tasks.append((spec, factory))
    return tasks


@settings(deadline=None, max_examples=15, derandomize=True)
@given(seed=st.integers(0, 10_000), G=st.sampled_from([2, 4, 8]))
def test_chaos_elastic_le_static(seed, G):
    """Elastic <= static survives fault injection: both sides wrap the
    SAME deterministic fault plans (faults fire on task-local progress,
    so penalties are schedule-independent) and both plan with the same
    per-fault reserve."""
    rng = np.random.default_rng(seed)
    plan_faults = FaultPlan(faults={})
    tasks = _chaos_workload(rng, G, plan_faults)
    specs = [s for s, _ in tasks]
    plan = solve(specs, G, "cp")
    static = execute_static(plan, G, {s.name: f for s, f in tasks})
    rt = ElasticClusterRuntime(G)
    for s, f in tasks:
        rt.submit(s, f)
    elastic = rt.run(initial=plan)
    assert elastic.makespan <= static.makespan + 1e-9
    injected = sum(1 for e in elastic.events
                   if e.kind is EventKind.REPLICA_FAILED)
    assert injected == plan_faults.total()
    assert set(elastic.results) == {s.name for s, _ in tasks}


def test_chaos_faulted_loss_identical():
    """Fault injection only costs time: the wrapped driver's result is
    bitwise identical to an un-faulted run of the same task."""
    def clean():
        return SimulatedTaskDriver("t", K=6, Z=3, total_steps=40,
                                   warmup_steps=4, step_time_s=0.02)
    base = clean()
    base.start(0.0)
    while not base.step_chunk().done:
        pass
    faulty = FaultyTaskDriver("t", clean(),
                              [Fault(0.2, 0.1), Fault(0.5, 0.3)], 0.1)
    faulty.start(0.0)
    wall = 0.0
    while True:
        ch = faulty.step_chunk()
        wall += ch.dt
        if ch.done:
            break
    assert faulty.faults_injected == 2
    assert wall > 40 * 0.02                      # retries were billed
    assert faulty.result() == base.result()


def test_pod_kill_requeues_and_completes():
    G = 4
    defs = [dict(K=8, Z=4, total=60, warm=4, step_time=0.02, gpus=2),
            dict(K=6, Z=2, total=40, warm=3, step_time=0.03, gpus=1),
            dict(K=12, Z=4, total=80, warm=5, step_time=0.01, gpus=4)]

    def build():
        rt = ElasticClusterRuntime(G)
        for i, kw in enumerate(defs):
            name = f"t{i}"
            spec = sim_task_spec(name, K=kw["K"], Z=kw["Z"],
                                 total_steps=kw["total"],
                                 warmup_steps=kw["warm"],
                                 step_time_s=kw["step_time"],
                                 gpus=kw["gpus"])

            def factory(name=name, kw=kw):
                return SimulatedTaskDriver(
                    name, K=kw["K"], Z=kw["Z"], total_steps=kw["total"],
                    warmup_steps=kw["warm"], step_time_s=kw["step_time"])
            rt.submit(spec, factory)
        return rt

    rt0 = build()
    base = rt0.run()
    rt = build()
    rt.begin()
    start, end = base.task_starts["t0"], base.task_ends["t0"]
    backoff = 0.3
    rt.inject_fault("t0", at=start + 0.5 * (end - start), backoff=backoff)
    while rt.step():
        pass
    rep = rt.report()
    kills = [e for e in rep.events if e.kind is EventKind.POD_KILLED]
    assert len(kills) == 1 and rep.pod_kills == 1
    assert set(rep.results) == {"t0", "t1", "t2"}    # everyone finished
    resumed = [e for e in rep.events
               if e.kind is EventKind.TASK_STARTED and e.task == "t0"]
    assert len(resumed) == 2                         # killed, then resumed
    # bounded degradation: at most the backoff plus a few atomic chunks
    # of replan slack on top of the fault-free makespan
    chunk = CHUNK_STEPS * max(kw["step_time"] for kw in defs)
    assert rep.makespan <= base.makespan + backoff + 3 * chunk + 1e-9


# ---------------------------------------------------------------------------
# wall-clock driver + hardening satellites
# ---------------------------------------------------------------------------

def test_run_forever_drains_submissions():
    import time as _time
    svc = TuningService(total_gpus=4)
    loop = svc.run_forever(poll_s=0.01)
    try:
        spec = sim_task_spec("w0", K=4, Z=2, total_steps=20,
                             warmup_steps=2, step_time_s=0.01, gpus=2)

        def factory():
            return SimulatedTaskDriver("w0", K=4, Z=2, total_steps=20,
                                       warmup_steps=2, step_time_s=0.01)
        h = svc.submit_spec(spec, factory)
        deadline = _time.monotonic() + 30.0
        while (not h.status().state.terminal
               and _time.monotonic() < deadline):
            _time.sleep(0.01)
        assert h.status().state.terminal
        assert "w0" in svc._results()
    finally:
        loop.stop()
    assert not loop.alive


def test_profile_store_corrupt_file_falls_back(tmp_path):
    from repro.sched.profiler import ProfileStore
    p = str(tmp_path / "prof.json")
    with open(p, "w") as f:
        f.write('{"version": 1, "entries": [tr')
    store = ProfileStore.load(p)                     # warns, never raises
    assert store.observations(("x", 1)) == 0


def test_publish_checkpoint_corrupt_artifact(tmp_path):
    from repro.serve.pool import AdapterPool, CorruptCheckpoint
    from tests.conftest import reduced_f32
    cfg = reduced_f32("paper-llama-tiny", num_layers=2, d_model=64,
                      vocab=64)
    pool = AdapterPool(cfg, Z=2)
    bad = str(tmp_path / "bad.npz")
    with open(bad, "wb") as f:
        f.write(b"\x00" * 64)
    with pytest.raises(CorruptCheckpoint):
        pool.publish_checkpoint(bad)
    assert pool.free_slots() == [0, 1]               # pool untouched
