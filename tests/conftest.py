"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py fakes the 512-device platform."""
import dataclasses

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def tiny_cfg():
    from repro.configs.registry import get_arch
    return dataclasses.replace(
        get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=128,
                                             vocab=256),
        dtype="float32")


def reduced_f32(name: str, **kw):
    from repro.configs.registry import get_arch
    return dataclasses.replace(get_arch(name).reduced(**kw), dtype="float32")
