"""Shared fixtures + pytest hardening. NOTE: no XLA_FLAGS here — tests run
on the single real CPU device; only launch/dryrun.py fakes the 512-device
platform."""
import dataclasses
import importlib.util
import pathlib
import sys

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")

# ---- hypothesis fallback ---------------------------------------------------
# CI installs the real package via `pip install -e .[test]`; bare containers
# fall back to the deterministic stub so property tests still collect + run.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _stub_path = pathlib.Path(__file__).with_name("_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _stub_path)
    _stub = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _stub
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis.strategies"] = _stub.strategies


def _has_tpu() -> bool:
    try:
        return any(d.platform == "tpu" for d in jax.devices())
    except RuntimeError:
        return False


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (deselect with -m 'not slow')")
    config.addinivalue_line(
        "markers", "tpu: needs real TPU hardware (Pallas non-interpret "
        "paths); auto-skipped on CPU-only runners")


def pytest_collection_modifyitems(config, items):
    if _has_tpu():
        return
    skip_tpu = pytest.mark.skip(
        reason="no TPU: Pallas non-interpret paths run interpret-mode only")
    for item in items:
        if "tpu" in item.keywords:
            item.add_marker(skip_tpu)


@pytest.fixture(scope="session")
def tiny_cfg():
    from repro.configs.registry import get_arch
    return dataclasses.replace(
        get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=128,
                                             vocab=256),
        dtype="float32")


def reduced_f32(name: str, **kw):
    from repro.configs.registry import get_arch
    return dataclasses.replace(get_arch(name).reduced(**kw), dtype="float32")
