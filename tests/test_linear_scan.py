"""Chunked linear-attention core vs the O(S) step oracle (RWKV6 + SSD)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.linear_scan import (chunked_linear_attention,
                                      linear_attention_decode_step,
                                      reference_linear_attention)


def make(Z, b, S, H, K, V, seed=0, decay_strength=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (Z, b, S, H, K))
    k = jax.random.normal(ks[1], (Z, b, S, H, K))
    v = jax.random.normal(ks[2], (Z, b, S, H, V))
    logw = -decay_strength * jnp.exp(jax.random.normal(ks[3], (Z, b, S, H, K)))
    u = jax.random.normal(ks[4], (H, K))
    return q, k, v, logw, u


@pytest.mark.parametrize("chunk", [4, 16, 32])
@pytest.mark.parametrize("mode", ["rwkv", "ssd"])
def test_chunked_matches_oracle(chunk, mode):
    q, k, v, logw, u = make(2, 2, 64, 3, 8, 8)
    doq = mode == "ssd"
    bonus = u if mode == "rwkv" else None
    y1, s1 = chunked_linear_attention(q, k, v, logw, bonus=bonus,
                                      decay_on_query=doq, chunk=chunk)
    y2, s2 = reference_linear_attention(q, k, v, logw, bonus=bonus,
                                        decay_on_query=doq)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_strong_decay_is_stable():
    """Exact log-space pair term: no overflow/NaN under brutal decay."""
    q, k, v, logw, u = make(1, 1, 128, 2, 8, 8, decay_strength=8.0)
    y, s = chunked_linear_attention(q, k, v, logw, bonus=u, chunk=32)
    assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.all(jnp.isfinite(s)))
    y2, s2 = reference_linear_attention(q, k, v, logw, bonus=u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               rtol=1e-3, atol=1e-3)


def test_initial_state_continuation():
    """Processing [0:S/2] then [S/2:S] with carried state == full pass."""
    q, k, v, logw, u = make(1, 2, 64, 2, 8, 8)
    half = 32
    y_full, s_full = chunked_linear_attention(q, k, v, logw, bonus=u, chunk=16)
    y1, s1 = chunked_linear_attention(
        q[:, :, :half], k[:, :, :half], v[:, :, :half], logw[:, :, :half],
        bonus=u, chunk=16)
    y2, s2 = chunked_linear_attention(
        q[:, :, half:], k[:, :, half:], v[:, :, half:], logw[:, :, half:],
        bonus=u, initial_state=s1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=2)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-4, atol=2e-4)


def test_decode_step_matches_chunked():
    q, k, v, logw, u = make(2, 1, 16, 2, 4, 4)
    y_full, s_full = chunked_linear_attention(q, k, v, logw, bonus=u, chunk=8)
    state = jnp.zeros((2, 1, 2, 4, 4))
    for t in range(16):
        y_t, state = linear_attention_decode_step(
            q[:, :, t], k[:, :, t], v[:, :, t], logw[:, :, t], state,
            bonus=u)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, :, -1]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_full),
                               rtol=2e-4, atol=2e-4)


@settings(deadline=None, max_examples=20)
@given(S=st.sampled_from([8, 24, 48]), chunk=st.sampled_from([4, 8, 24]),
       seed=st.integers(0, 100), mode=st.booleans())
def test_property_chunk_invariance(S, chunk, seed, mode):
    """Output is invariant to chunk size (associativity of the scan)."""
    if S % chunk:
        chunk = S
    q, k, v, logw, u = make(1, 1, S, 1, 4, 4, seed=seed)
    bonus = None if mode else u
    y1, s1 = chunked_linear_attention(q, k, v, logw, bonus=bonus,
                                      decay_on_query=mode, chunk=chunk)
    y2, s2 = chunked_linear_attention(q, k, v, logw, bonus=bonus,
                                      decay_on_query=mode, chunk=S)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=5e-4, atol=5e-4)
