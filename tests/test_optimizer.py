"""Per-slot AdamW: slot-vector hyperparams, clipping, masking, freezing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw


def tiny_tree(Z=3, L=2, d=4, r=2, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 2)
    return {"t": {"A": jax.random.normal(ks[0], (L, Z, d, r)),
                  "B": jax.random.normal(ks[1], (L, Z, r, d))}}


def test_per_slot_lr_vector():
    Z = 3
    params = tiny_tree(Z)
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    state = adamw.init_state(params, Z)
    hp = adamw.SlotHParams.broadcast(Z, lr=0.0, wd=0.0, grad_clip=0.0)
    hp = hp.replace_slot(1, lr=0.1)
    active = jnp.ones((Z,), jnp.int32)
    p2, s2 = adamw.apply_updates(params, grads, state, hp, active)
    d = jax.tree_util.tree_map(lambda a, b: a - b, p2, params)
    assert float(jnp.abs(d["t"]["A"][:, 0]).max()) == 0.0    # lr=0
    assert float(jnp.abs(d["t"]["A"][:, 1]).max()) > 0.0     # lr=0.1
    assert float(jnp.abs(d["t"]["A"][:, 2]).max()) == 0.0


def test_inactive_slot_fully_frozen():
    Z = 2
    params = tiny_tree(Z)
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    state = adamw.init_state(params, Z)
    hp = adamw.SlotHParams.broadcast(Z, lr=0.1, wd=0.1)
    active = jnp.array([1, 0], jnp.int32)
    p2, s2 = adamw.apply_updates(params, grads, state, hp, active)
    np.testing.assert_array_equal(np.asarray(p2["t"]["A"][:, 1]),
                                  np.asarray(params["t"]["A"][:, 1]))
    assert float(jnp.abs(s2.mu["t"]["A"][:, 1]).max()) == 0.0
    assert int(s2.count[1]) == 0 and int(s2.count[0]) == 1


def test_per_slot_grad_clip():
    Z = 2
    params = tiny_tree(Z)
    # slot 0 huge grads, slot 1 small
    grads = jax.tree_util.tree_map(
        lambda x: jnp.ones_like(x).at[:, 0].mul(1e6), params)
    norms = adamw.per_slot_global_norm(grads)
    assert float(norms[0]) > 1e6 and float(norms[1]) < 100
    state = adamw.init_state(params, Z)
    hp = adamw.SlotHParams.broadcast(Z, lr=0.1, wd=0.0, grad_clip=1.0)
    active = jnp.ones((Z,), jnp.int32)
    p2, _ = adamw.apply_updates(params, grads, state, hp, active)
    # first Adam step size is ~lr regardless, but moments must be clipped
    assert bool(jnp.all(jnp.isfinite(p2["t"]["A"])))


def test_bias_correction_first_step_size():
    """First update = lr * g/|g| (+wd) per element for Adam."""
    Z = 1
    params = {"t": {"A": jnp.zeros((1, 1, 2, 2))}}
    grads = {"t": {"A": jnp.full((1, 1, 2, 2), 0.5)}}
    state = adamw.init_state(params, Z)
    hp = adamw.SlotHParams.broadcast(Z, lr=0.01, wd=0.0, grad_clip=0.0)
    p2, _ = adamw.apply_updates(params, grads, state, hp,
                                jnp.ones((1,), jnp.int32))
    np.testing.assert_allclose(np.asarray(p2["t"]["A"]), -0.01, rtol=1e-4)


def test_reset_slot():
    Z = 2
    params = tiny_tree(Z)
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    state = adamw.init_state(params, Z)
    hp = adamw.SlotHParams.broadcast(Z, lr=0.1)
    _, s2 = adamw.apply_updates(params, grads, state, hp,
                                jnp.ones((Z,), jnp.int32))
    s3 = adamw.reset_slot(s2, 0)
    assert float(jnp.abs(s3.mu["t"]["A"][:, 0]).max()) == 0.0
    assert float(jnp.abs(s3.mu["t"]["A"][:, 1]).max()) > 0.0
    assert int(s3.count[0]) == 0 and int(s3.count[1]) == 1
