"""Elastic cluster runtime (paper §7.2): determinism, plan validity,
and the elastic <= static makespan guarantee (anomaly safety)."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.sched import profiler
from repro.sched.cluster import (ElasticClusterRuntime, SimulatedTaskDriver,
                                 execute_static, sim_colo_spec,
                                 sim_task_spec)
from repro.sched.events import EventKind, ProgressEvent
from repro.sched.inter_task import (FusionProfile, ReplicaState, TaskSpec,
                                    diff_schedules, list_schedule,
                                    lower_bound_fused, plan_fused, solve,
                                    solve_residual)


def make_task(name, *, K, Z, total, warm, step_time, gpus, exits):
    spec = sim_task_spec(name, K=K, Z=Z, total_steps=total,
                         warmup_steps=warm, step_time_s=step_time, gpus=gpus)

    def factory():
        return SimulatedTaskDriver(name, K=K, Z=Z, total_steps=total,
                                   warmup_steps=warm, step_time_s=step_time,
                                   exit_step=exits)
    return spec, factory


def random_workload(rng, G):
    """Heterogeneous mix: mixed K, Z, budgets, step times, exit patterns."""
    n = int(rng.integers(2, 7))
    tasks = []
    for i in range(n):
        K = int(rng.integers(2, 20))
        Z = int(rng.integers(1, 6))
        total = int(rng.integers(10, 150))
        warm = int(rng.integers(1, max(total // 4, 2)))
        step_time = float(rng.uniform(0.005, 0.05))
        gpus = int(rng.integers(1, G + 1))
        n_exits = int(rng.integers(0, K + 1))
        exits = {int(j): int(rng.integers(1, total)) for j in
                 rng.choice(K, size=n_exits, replace=False)}
        tasks.append(make_task(f"t{i}", K=K, Z=Z, total=total, warm=warm,
                               step_time=step_time, gpus=gpus, exits=exits))
    return tasks


def run_both(tasks, G):
    specs = [s for s, _ in tasks]
    plan = solve(specs, G, "cp")
    static = execute_static(plan, G, {s.name: f for s, f in tasks})
    rt = ElasticClusterRuntime(G)
    for s, f in tasks:
        rt.submit(s, f)
    elastic = rt.run(initial=plan)
    return plan, static, elastic


FIXED = [dict(K=16, Z=4, total=100, warm=5, step_time=0.01, gpus=4,
              exits={0: 20, 1: 30}),
         dict(K=8, Z=4, total=80, warm=4, step_time=0.02, gpus=2,
              exits={2: 10}),
         dict(K=12, Z=4, total=120, warm=6, step_time=0.015, gpus=2,
              exits={}),
         dict(K=6, Z=2, total=60, warm=3, step_time=0.03, gpus=1,
              exits={0: 8, 3: 12})]


def fixed_workload():
    return [make_task(f"t{i}", **kw) for i, kw in enumerate(FIXED)]


# ---------------------------------------------------------------------------
# determinism + validity
# ---------------------------------------------------------------------------

def test_event_ordering_deterministic():
    """Two runs of the same seeded workload produce identical event logs,
    starts, and makespans."""
    reports = [run_both(fixed_workload(), G=4)[2] for _ in range(2)]
    a, b = reports
    assert a.makespan == b.makespan
    assert a.task_starts == b.task_starts
    assert a.task_ends == b.task_ends
    assert ([(e.kind, e.task, e.time, e.job) for e in a.events]
            == [(e.kind, e.task, e.time, e.job) for e in b.events])


def test_realized_schedule_validates_and_replans_fire():
    G = 4
    plan, static, elastic = run_both(fixed_workload(), G)
    # no per-GPU overlap, capacity respected, demands satisfied
    elastic.realized.validate(G)
    static.realized.validate(G)
    assert elastic.replans >= 1
    assert elastic.plans_adopted + elastic.plans_rejected == elastic.replans
    # every task ran exactly once on the demanded number of GPUs
    by_name = {p.task.name: p for p in elastic.realized.placements}
    for spec, _ in fixed_workload():
        assert len(by_name[spec.name].gpu_ids) == spec.gpus


def test_gpu_utilization_accounting():
    G = 4
    _, static, elastic = run_both(fixed_workload(), G)
    for rep in (static, elastic):
        per_gpu = rep.per_gpu_utilization()
        assert len(per_gpu) == G
        assert all(-1e-9 <= u <= 1 + 1e-9 for u in per_gpu)
        total = sum(rep.gpu_busy) / (G * rep.makespan)
        assert abs(total - rep.utilization) < 1e-9
    # same actual work executed under both strategies
    assert abs(sum(static.gpu_busy) - sum(elastic.gpu_busy)) < 1e-6


def test_early_exit_reclaims_gpus_and_beats_static():
    """The §7.2 scenario: a cluster-wide task whose survivors all exit
    shortly after warmup must hand its GPUs to the pending task early."""
    G = 4
    tasks = [make_task("big", K=8, Z=4, total=200, warm=10, step_time=0.02,
                       gpus=4, exits={j: 15 for j in range(8)}),
             make_task("next", K=4, Z=2, total=100, warm=5, step_time=0.02,
                       gpus=4, exits={})]
    plan, static, elastic = run_both(tasks, G)
    assert elastic.makespan < static.makespan - 1e-9
    assert elastic.task_starts["next"] < \
        {p.task.name: p.start for p in plan.placements}["next"] - 1e-9
    assert elastic.utilization > static.utilization
    kinds = {e.kind for e in elastic.events}
    assert EventKind.JOB_EXITED in kinds
    assert EventKind.REPLAN in kinds


# ---------------------------------------------------------------------------
# property: elastic never loses to the static plan
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=30)
@given(seed=st.integers(0, 10_000), G=st.sampled_from([2, 4, 8]))
def test_property_elastic_never_worse_than_static(seed, G):
    rng = np.random.default_rng(seed)
    tasks = random_workload(rng, G)
    plan, static, elastic = run_both(tasks, G)
    assert elastic.makespan <= static.makespan + 1e-9
    elastic.realized.validate(G)
    # starts never later than the static plan (the adoption invariant)
    planned = {p.task.name: p.start for p in plan.placements}
    for name, start in elastic.task_starts.items():
        assert start <= planned[name] + 1e-9


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 10_000), G=st.sampled_from([4, 8]))
def test_property_replan_schedules_always_valid(seed, G):
    """Residual re-solves over a busy skyline are themselves valid
    schedules and never place work before the skyline frees."""
    rng = np.random.default_rng(seed)
    specs = [TaskSpec(f"p{i}", float(rng.uniform(0.5, 8.0)),
                      int(rng.integers(1, G + 1)))
             for i in range(int(rng.integers(1, 8)))]
    sky = [float(rng.uniform(0.0, 5.0)) for _ in range(G)]
    s = solve_residual(specs, G, sky, "cp")
    s.validate(G)
    for p in s.placements:
        for g in p.gpu_ids:
            assert p.start >= sky[g] - 1e-9
    assert s.makespan >= max(sky) - 1e-9


# ---------------------------------------------------------------------------
# components: diffing, skyline solver, residual estimation
# ---------------------------------------------------------------------------

def test_diff_schedules_reports_moves():
    a = TaskSpec("a", 2.0, 1)
    b = TaskSpec("b", 1.0, 1)
    old = list_schedule([a, b], 1)
    new = list_schedule([b, a], 1)
    deltas = {d.task: d for d in diff_schedules(old, new)}
    assert deltas["b"].moved_earlier
    assert not deltas["a"].moved_earlier
    assert diff_schedules(old, old) == []


def test_skyline_list_schedule_respects_busy_gpus():
    s = list_schedule([TaskSpec("x", 1.0, 2)], 2, free_at=[3.0, 0.5])
    assert s.placements[0].start == 3.0      # must wait for both GPUs
    assert s.makespan == 4.0


def test_lifecycle_steps_and_reestimation():
    # 10 jobs on 4 slots: 3 warmup waves; top-3 survivors: 1 continue wave
    assert profiler.lifecycle_steps(10, 4, 5, 50, survivors=3) == \
        3 * 5 + 1 * 45
    # shrink: fewer survivors than slots never increases the estimate
    full = profiler.reestimate_duration(0.1, 10, 4, 5, 50, survivors=3)
    fewer = profiler.reestimate_duration(0.1, 10, 4, 5, 50, survivors=1)
    assert fewer <= full
    assert profiler.residual_duration(-5, 0.1) == 0.0


def test_sim_driver_residual_monotone_and_upper_bound():
    """The driver's residual estimate never grows and always covers the
    realized remaining duration (what the adoption proof relies on)."""
    drv = SimulatedTaskDriver("t", K=9, Z=3, total_steps=60, warmup_steps=4,
                              step_time_s=0.01, exit_step={1: 10, 4: 20})
    spec = sim_task_spec("t", K=9, Z=3, total_steps=60, warmup_steps=4,
                         step_time_s=0.01, gpus=1)
    drv.start(0.0)
    elapsed, chunks = 0.0, []
    assert drv.residual_estimate() <= spec.duration + 1e-9
    while True:
        before = drv.residual_estimate()
        c = drv.step_chunk()
        elapsed += c.dt
        chunks.append(c)
        if c.done:
            break
        assert drv.residual_estimate() <= before + 1e-9
    assert drv.residual_estimate() == 0.0
    assert elapsed <= spec.duration + 1e-9
    ev_kinds = [e.kind for c in chunks for e in c.events]
    assert EventKind.WARMUP_SELECTION in ev_kinds
    assert EventKind.TASK_COMPLETED in ev_kinds


def test_runtime_rejects_duplicate_and_oversized_tasks():
    rt = ElasticClusterRuntime(2)
    spec, fac = make_task("a", K=2, Z=1, total=10, warm=1, step_time=0.01,
                          gpus=1, exits={})
    rt.submit(spec, fac)
    with pytest.raises(AssertionError):
        rt.submit(dataclasses.replace(spec, gpus=3), fac)
    rt.submit(dataclasses.replace(spec, name="a"), fac)   # dup name
    with pytest.raises(AssertionError):
        rt.run()


def test_progress_event_stamping():
    e = ProgressEvent(kind=EventKind.JOB_EXITED, task="t", job="t/j0",
                      reason="diverging")
    assert e.shrinks()
    assert e.stamped(3.5).time == 3.5
    assert not ProgressEvent(kind=EventKind.TASK_PROGRESS, task="t").shrinks()


# ---------------------------------------------------------------------------
# cross-task co-location (shared-backbone replicas)
# ---------------------------------------------------------------------------

FUSE_KEY = ("arch-a", 1, 4, 64, "sft")


def colo_workload(G=2):
    """One fusable long host + an exclusive hog + fusable small tasks:
    exclusive placement must queue the small tasks behind busy GPUs."""
    return [
        make_task("host", K=8, Z=4, total=400, warm=20, step_time=0.01,
                  gpus=1, exits={}) + (sim_colo_spec(FUSE_KEY, K=8, Z=4),),
        make_task("hog", K=8, Z=4, total=400, warm=20, step_time=0.01,
                  gpus=1, exits={}) + (None,),
        make_task("s1", K=2, Z=2, total=60, warm=3, step_time=0.01,
                  gpus=1, exits={}) + (sim_colo_spec(FUSE_KEY, K=2, Z=2),),
        make_task("s2", K=2, Z=2, total=60, warm=3, step_time=0.01,
                  gpus=1, exits={}) + (sim_colo_spec(FUSE_KEY, K=2, Z=2),),
    ]


def run_colo(tasks, G, colocate):
    specs = [s for s, _, _ in tasks]
    plan = solve(specs, G, "cp")
    static = execute_static(plan, G, {s.name: f for s, f, _ in tasks})
    rt = ElasticClusterRuntime(G, colocate=colocate)
    for s, f, c in tasks:
        rt.submit(s, f, colo=c)
    return plan, static, rt.run(initial=plan)


def test_colocation_fuses_and_beats_exclusive():
    G = 2
    _, static, excl = run_colo(colo_workload(G), G, colocate=False)
    _, _, colo = run_colo(colo_workload(G), G, colocate=True)
    # small tasks fused onto the host replica instead of queueing
    assert colo.colocated == {"s1": "host", "s2": "host"}
    assert excl.colocated == {}
    assert EventKind.TASK_FUSED in {e.kind for e in colo.events}
    # fused small tasks start earlier and the cluster clears sooner
    assert colo.task_starts["s1"] < excl.task_starts["s1"] - 1e-9
    assert colo.makespan < excl.makespan - 1e-9
    assert colo.makespan <= static.makespan + 1e-9
    # every task still delivers its result, attributed per task
    assert set(colo.results) == {"host", "hog", "s1", "s2"}
    for name in ("s1", "s2"):
        assert colo.results[name]["task"] == name
        assert colo.task_ends[name] <= colo.task_ends["host"] + 1e-9 or \
            colo.task_ends[name] <= colo.makespan + 1e-9
    # the realized schedule (replica owners only) still validates
    colo.realized.validate(G)


def test_colocation_deterministic():
    a = run_colo(colo_workload(2), 2, colocate=True)[2]
    b = run_colo(colo_workload(2), 2, colocate=True)[2]
    assert a.makespan == b.makespan
    assert a.task_starts == b.task_starts
    assert a.task_ends == b.task_ends
    assert ([(e.kind, e.task, e.time) for e in a.events]
            == [(e.kind, e.task, e.time) for e in b.events])


def test_colocation_respects_replica_capacity():
    """A guest whose slot need exceeds the replica's reclaimable headroom
    must NOT fuse (it waits for exclusive placement instead)."""
    G = 2
    tasks = [
        make_task("host", K=8, Z=4, total=400, warm=20, step_time=0.01,
                  gpus=1, exits={}) + (sim_colo_spec(FUSE_KEY, K=8, Z=4),),
        make_task("hog", K=8, Z=4, total=400, warm=20, step_time=0.01,
                  gpus=1, exits={}) + (None,),
        # needs 4 slots; host's continue-phase bound is top_k(8)=2, so
        # headroom never reaches 4 on a 4-slot replica
        make_task("wide", K=8, Z=4, total=60, warm=3, step_time=0.01,
                  gpus=1, exits={}) + (sim_colo_spec(FUSE_KEY, K=8, Z=4),),
    ]
    _, static, rep = run_colo(tasks, G, colocate=True)
    assert rep.colocated == {}
    assert rep.makespan <= static.makespan + 1e-9


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 10_000), G=st.sampled_from([2, 4]))
def test_property_colocation_never_worse_than_static(seed, G):
    """elastic <= static survives co-location: fusion only ever starts
    pending work earlier inside existing replica occupancy."""
    rng = np.random.default_rng(seed)
    tasks = []
    for i, (spec, factory) in enumerate(random_workload(rng, G)):
        fusable = rng.random() < 0.7
        colo = None
        if fusable:
            # reconstruct lifecycle shape from the driver for the spec
            drv = factory()
            colo = sim_colo_spec(("shared", spec.gpus), K=drv.K, Z=drv.Z)
        tasks.append((spec, factory, colo))
    specs = [s for s, _, _ in tasks]
    plan = solve(specs, G, "cp")
    static = execute_static(plan, G, {s.name: f for s, f, _ in tasks})
    rt = ElasticClusterRuntime(G, colocate=True)
    for s, f, c in tasks:
        rt.submit(s, f, colo=c)
    rep = rt.run(initial=plan)
    assert rep.makespan <= static.makespan + 1e-9
    rep.realized.validate(G)
    assert set(rep.results) == {s.name for s, _, _ in tasks}
    for name, host in rep.colocated.items():
        assert rep.task_starts[name] <= \
            {p.task.name: p.start for p in plan.placements}[name] + 1e-9
        assert host in rep.task_starts


def test_cancelling_host_cancels_unfinished_guests():
    """Cancelling a replica owner drops its unfinished tenants' slots:
    they must surface as CANCELLED (no results, no fake completions),
    while tenants that already finished keep their results."""
    G = 2
    tasks = colo_workload(G)
    specs = [s for s, _, _ in tasks]
    plan = solve(specs, G, "cp")
    rt = ElasticClusterRuntime(G, colocate=True)
    for s, f, c in tasks:
        rt.submit(s, f, colo=c)
    rt.begin(plan)
    # drive until both small tasks fused, then past s1's completion
    while rt.step():
        fused = {e.task for e in rt.event_log
                 if e.kind is EventKind.TASK_FUSED}
        if "s1" in rt.results_map and "s2" in fused:
            break
    assert "s2" not in rt.results_map          # s2 still mid-flight
    rt.cancel("host")
    while rt.step():
        pass
    rep = rt.report()
    assert "host" in rep.cancelled
    assert "s2" in rep.cancelled               # unfinished guest cancelled
    assert "s2" not in rep.results
    assert rep.results["s1"]["task"] == "s1"   # finished guest kept
    kinds = [(e.kind, e.task) for e in rep.events]
    assert (EventKind.TASK_CANCELLED, "s2") in kinds


# ---------------------------------------------------------------------------
# ragged co-location (mixed per-adapter batch sizes on one replica)
# ---------------------------------------------------------------------------

# width-free fuse key: since slots went ragged, compatibility is only
# (arch, gpus, loss kind) — batch size / seq len enter as a token budget
RKEY = ("arch-a", 1, "sft")


def ragged_workload(G=2, mem=None):
    """Host (b=4) plus small tasks with DIFFERENT widths (b=8, b=2):
    same-key-only fusion (PR3 keys bake b in) cannot fuse them; ragged
    admission can."""
    return [
        make_task("host", K=8, Z=4, total=400, warm=20, step_time=0.01,
                  gpus=1, exits={}) +
        (sim_colo_spec(RKEY, K=8, Z=4, per_adapter_batch=4, seq_len=64,
                       replica_slots=8, mem=mem),),
        make_task("hog", K=8, Z=4, total=400, warm=20, step_time=0.01,
                  gpus=1, exits={}) + (None,),
        make_task("wide", K=2, Z=2, total=60, warm=3, step_time=0.01,
                  gpus=1, exits={}) +
        (sim_colo_spec(RKEY, K=2, Z=2, per_adapter_batch=8, seq_len=64),),
        make_task("narrow", K=2, Z=2, total=60, warm=3, step_time=0.01,
                  gpus=1, exits={}) +
        (sim_colo_spec(RKEY, K=2, Z=2, per_adapter_batch=2, seq_len=64),),
    ]


def test_ragged_colocation_fuses_mixed_batch_sizes():
    """Guests whose per-adapter batch differs from the host's (8 and 2 vs
    4) fuse onto the host replica under the relaxed key and the cluster
    clears sooner than exclusive placement."""
    G = 2
    _, static, excl = run_colo(ragged_workload(G), G, colocate=False)
    _, _, colo = run_colo(ragged_workload(G), G, colocate=True)
    assert colo.colocated == {"narrow": "host", "wide": "host"}
    assert excl.results == colo.results
    assert colo.makespan < excl.makespan - 1e-9
    assert colo.makespan <= static.makespan + 1e-9
    colo.realized.validate(G)


def test_same_key_fusion_cannot_fuse_mixed_widths():
    """Baked-width keys (the pre-ragged fuse rule) reject every
    mixed-batch guest that ragged admission accepts — the A/B the bench
    quantifies."""
    G = 2
    tasks = ragged_workload(G)
    # rebuild with PR3-style keys that embed (b, seq)
    legacy = []
    for spec, factory, colo in tasks:
        if colo is not None:
            colo = dataclasses.replace(
                colo, fuse_key=RKEY + (colo.per_adapter_batch, 64))
        legacy.append((spec, factory, colo))
    _, _, same = run_colo(legacy, G, colocate=True)
    _, _, ragged = run_colo(tasks, G, colocate=True)
    assert same.colocated == {}                 # b=8 / b=2 vs host b=4
    assert ragged.colocated == {"narrow": "host", "wide": "host"}
    assert ragged.makespan < same.makespan - 1e-9


def test_ragged_admission_respects_token_budget():
    """The §A.3 token budget gates mixed-width fusion: with a tight
    memory model the wide (b=8) guest must NOT fuse while the narrow
    (b=2) one does — slot counts alone would admit both."""
    from repro.sched.intra_task import MemoryModel
    G = 2
    # host bound: 4 slots * b=4 * seq 64 = 1024 tokens; narrow adds
    # 2*2*64 = 256 (fits 1500); wide would add 2*8*64 = 1024 (rejected)
    mem = MemoryModel(k0=0.0, k1=1.0, seq_len=64, capacity=1500,
                      safety_margin=1.0)
    _, static, rep = run_colo(ragged_workload(G, mem=mem), G, colocate=True)
    assert rep.colocated == {"narrow": "host"}
    assert rep.makespan <= static.makespan + 1e-9


def test_admit_cross_task_token_accounting():
    """Unit: admission sorts by per-slot token width and admits while
    M_hat(total tokens) stays inside the margin."""
    from repro.sched.intra_task import (ColoRequest, MemoryModel,
                                        admit_cross_task)
    mem = MemoryModel(k0=100.0, k1=1.0, seq_len=32, capacity=2000,
                      safety_margin=1.0)
    resident = [ColoRequest("host", slots=4, per_adapter_batch=4,
                            seq_len=32)]                      # 512 tokens
    pending = [
        ColoRequest("wide", slots=2, per_adapter_batch=8, seq_len=32),
        ColoRequest("narrow", slots=2, per_adapter_batch=2, seq_len=32),
        ColoRequest("longseq", slots=1, per_adapter_batch=2, seq_len=128),
    ]
    # widths: wide 256, longseq 256, narrow 64 -> order (wide, longseq,
    # narrow) with name tiebreak; budget 1900 - 512 = 1388 tokens
    got = admit_cross_task(resident, pending, capacity_slots=16, mem=mem)
    assert got == ["longseq", "wide", "narrow"]
    # tighter budget (800 - 100 = 700 tokens): host 512 + narrow 64*2
    # fits; wide (+512) and longseq (+256) both exceed it
    tight = MemoryModel(k0=100.0, k1=1.0, seq_len=32, capacity=800,
                        safety_margin=1.0)
    got = admit_cross_task(resident, pending, capacity_slots=16, mem=tight)
    assert got == ["narrow"]
    # legacy callers without seq fall back to the model's fit seq
    legacy = [ColoRequest("legacy", slots=2, per_adapter_batch=2)]
    got = admit_cross_task(resident, legacy, capacity_slots=16, mem=mem)
    assert got == ["legacy"]


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 10_000), G=st.sampled_from([2, 4]))
def test_property_ragged_colocation_never_worse_than_static(seed, G):
    """elastic <= static survives RAGGED co-location: fusing guests with
    arbitrary (b, seq) widths under the token budget only ever starts
    pending work earlier inside existing replica occupancy."""
    from repro.sched.intra_task import MemoryModel
    rng = np.random.default_rng(seed)
    tasks = []
    for i, (spec, factory) in enumerate(random_workload(rng, G)):
        colo = None
        if rng.random() < 0.7:
            drv = factory()
            mem = None
            if rng.random() < 0.5:
                mem = MemoryModel(
                    k0=0.0, k1=1.0, seq_len=64,
                    capacity=float(rng.integers(2_000, 40_000)),
                    safety_margin=1.0)
            colo = sim_colo_spec(
                ("shared", spec.gpus), K=drv.K, Z=drv.Z,
                per_adapter_batch=int(rng.integers(1, 17)),
                seq_len=int(rng.choice([16, 64, 256])),
                replica_slots=int(rng.integers(drv.Z, 2 * drv.Z + 1)),
                mem=mem)
        tasks.append((spec, factory, colo))
    specs = [s for s, _, _ in tasks]
    plan = solve(specs, G, "cp")
    static = execute_static(plan, G, {s.name: f for s, f, _ in tasks})
    rt = ElasticClusterRuntime(G, colocate=True)
    for s, f, c in tasks:
        rt.submit(s, f, colo=c)
    rep = rt.run(initial=plan)
    assert rep.makespan <= static.makespan + 1e-9
    rep.realized.validate(G)
    assert set(rep.results) == {s.name for s, _, _ in tasks}


# ---------------------------------------------------------------------------
# rank-aware admission (rank-local grouped GEMM: true-rank budgeting)
# ---------------------------------------------------------------------------

def test_admit_cross_task_rank_weighted_accounting():
    """Unit: a rank-aware model (k2 > 0) charges each task's TRUE rank;
    requests without rank info are charged r_max — so a mixed-rank queue
    admits strictly more than rank-masked budgeting allows."""
    from repro.sched.intra_task import (ColoRequest, MemoryModel,
                                        admit_cross_task)
    # budget is pure rank-tokens: slots * b * seq * rank
    mem = MemoryModel(k0=0.0, k1=0.0, seq_len=32, capacity=200_000,
                      safety_margin=1.0, k2=1.0, r_max=64)
    resident = [ColoRequest("host", slots=2, per_adapter_batch=4,
                            seq_len=32, lora_rank=16)]      # 4096 rank-tok
    sweep = [ColoRequest(f"g{r}", slots=2, per_adapter_batch=2, seq_len=32,
                         lora_rank=r) for r in (4, 8, 16, 32, 64)]
    masked = [dataclasses.replace(g, lora_rank=None) for g in sweep]
    # masked: every guest billed 2*2*32*64 = 8192 -> (200000-4096)/8192
    # admits all five anyway with this loose budget; tighten:
    tight = dataclasses.replace(mem, capacity=20_000)
    got_masked = admit_cross_task(resident, masked, 16, tight)
    got_true = admit_cross_task(resident, sweep, 16, tight)
    # true charges: r64=8192, r32=4096, r16=2048, r8=1024, r4=512
    # budget 20000-4096=15904: desc greedy admits 64,32,16,8,4 (15872)
    assert got_true == ["g64", "g32", "g16", "g8", "g4"]
    # masked charges 8192 each: only one fits
    assert got_masked == ["g4"] or len(got_masked) == 1
    assert len(got_true) > len(got_masked)


def test_rank_neutral_model_unchanged():
    """k2 == 0 (every pre-rank-local caller): rank fields are inert and
    admission reduces to the token budget exactly."""
    from repro.sched.intra_task import (ColoRequest, MemoryModel,
                                        admit_cross_task)
    mem = MemoryModel(k0=100.0, k1=1.0, seq_len=32, capacity=2000,
                      safety_margin=1.0)
    resident = [ColoRequest("host", slots=4, per_adapter_batch=4,
                            seq_len=32)]
    pending = [
        ColoRequest("wide", slots=2, per_adapter_batch=8, seq_len=32,
                    lora_rank=64),
        ColoRequest("narrow", slots=2, per_adapter_batch=2, seq_len=32,
                    lora_rank=4),
    ]
    bare = [dataclasses.replace(p, lora_rank=None) for p in pending]
    assert (admit_cross_task(resident, pending, 16, mem)
            == admit_cross_task(resident, bare, 16, mem))


@settings(deadline=None, max_examples=50)
@given(seed=st.integers(0, 10_000))
def test_property_true_rank_admits_geq_masked(seed):
    """On a uniform-width rank-sweep queue (the bench's tuning mix shape),
    true-rank budgeting admits AT LEAST as many guests as r_max-masked
    budgeting: each guest's true charge is <= its masked charge and all
    masked charges are equal, so desc-greedy can only gain."""
    from repro.sched.intra_task import (ColoRequest, MemoryModel,
                                        admit_cross_task)
    rng = np.random.default_rng(seed)
    r_max = int(rng.choice([16, 32, 64]))
    mem = MemoryModel(k0=0.0, k1=1.0, seq_len=64,
                      capacity=float(rng.integers(10_000, 2_000_000)),
                      safety_margin=1.0, k2=1.0, r_max=r_max)
    resident = [ColoRequest("host", slots=int(rng.integers(1, 5)),
                            per_adapter_batch=4, seq_len=64,
                            lora_rank=r_max)]
    n = int(rng.integers(1, 10))
    sweep = [ColoRequest(f"g{i}", slots=2, per_adapter_batch=2, seq_len=64,
                         lora_rank=int(rng.integers(1, r_max + 1)))
             for i in range(n)]
    masked = [dataclasses.replace(g, lora_rank=None) for g in sweep]
    got_true = admit_cross_task(resident, sweep, 64, mem)
    got_masked = admit_cross_task(resident, masked, 64, mem)
    assert len(got_true) >= len(got_masked)
    assert set(got_masked) <= set(sweep_names := {g.name for g in sweep})
    assert set(got_true) <= sweep_names


def test_ranklocal_colocation_fuses_low_rank_guests():
    """Cluster-level: under a tight rank-aware budget, a low-rank guest
    fuses onto the host replica while the same guest charged at r_max
    (rank unknown) must wait for exclusive placement."""
    from repro.sched.intra_task import MemoryModel
    G = 2
    # pure rank-token budget; host: 4 slots * b4 * seq64 * r16 = 16384;
    # guest true: 2*2*64*4 = 1024 (fits 20000); masked: 2*2*64*64 = 16384
    # (rejected)
    mem = MemoryModel(k0=0.0, k1=0.0, seq_len=64, capacity=20_000,
                      safety_margin=1.0, k2=1.0, r_max=64)

    def tasks(guest_rank):
        return [
            make_task("host", K=8, Z=4, total=400, warm=20, step_time=0.01,
                      gpus=1, exits={}) +
            (sim_colo_spec(RKEY, K=8, Z=4, per_adapter_batch=4, seq_len=64,
                           replica_slots=8, mem=mem, lora_rank=16),),
            make_task("hog", K=8, Z=4, total=400, warm=20, step_time=0.01,
                      gpus=1, exits={}) + (None,),
            make_task("lowrank", K=2, Z=2, total=60, warm=3, step_time=0.01,
                      gpus=1, exits={}) +
            (sim_colo_spec(RKEY, K=2, Z=2, per_adapter_batch=2, seq_len=64,
                           lora_rank=guest_rank),),
        ]

    _, static, local = run_colo(tasks(4), G, colocate=True)
    _, _, masked = run_colo(tasks(None), G, colocate=True)
    assert local.colocated == {"lowrank": "host"}
    assert masked.colocated == {}
    assert local.makespan < masked.makespan - 1e-9
    assert local.makespan <= static.makespan + 1e-9
    assert local.results == masked.results


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 10_000), G=st.sampled_from([2, 4]))
def test_property_ranklocal_colocation_never_worse_than_static(seed, G):
    """elastic <= static survives RANK-AWARE co-location: fusing guests
    admitted under the true-rank FLOP-token budget only ever starts
    pending work earlier inside existing replica occupancy."""
    from repro.sched.intra_task import MemoryModel
    rng = np.random.default_rng(seed)
    tasks = []
    for i, (spec, factory) in enumerate(random_workload(rng, G)):
        colo = None
        if rng.random() < 0.7:
            drv = factory()
            mem = None
            if rng.random() < 0.6:
                mem = MemoryModel(
                    k0=0.0, k1=1.0, seq_len=64,
                    capacity=float(rng.integers(2_000, 4_000_000)),
                    safety_margin=1.0, k2=float(rng.choice([0.0, 1.0])),
                    r_max=64)
            colo = sim_colo_spec(
                ("shared", spec.gpus), K=drv.K, Z=drv.Z,
                per_adapter_batch=int(rng.integers(1, 17)),
                seq_len=int(rng.choice([16, 64, 256])),
                replica_slots=int(rng.integers(drv.Z, 2 * drv.Z + 1)),
                mem=mem,
                lora_rank=(int(rng.integers(1, 65))
                           if rng.random() < 0.7 else None))
        tasks.append((spec, factory, colo))
    specs = [s for s, _, _ in tasks]
    plan = solve(specs, G, "cp")
    static = execute_static(plan, G, {s.name: f for s, f, _ in tasks})
    rt = ElasticClusterRuntime(G, colocate=True)
    for s, f, c in tasks:
        rt.submit(s, f, colo=c)
    rep = rt.run(initial=plan)
    assert rep.makespan <= static.makespan + 1e-9
    rep.realized.validate(G)
    assert set(rep.results) == {s.name for s, _, _ in tasks}


# ---------------------------------------------------------------------------
# fusion-aware planning + slot-level preemption/migration
# ---------------------------------------------------------------------------

def test_plan_fused_places_into_replica_slots():
    """plan_fused assigns a fitting task to a replica slot, leaves the
    rest exclusive, and never projects worse than the exclusive plan."""
    t_fit = TaskSpec("fit", duration=5.0, gpus=2)
    t_big = TaskSpec("big", duration=20.0, gpus=2)
    rep = ReplicaState(host="h", fuse_key=("k",), gpu_ids=(0, 1),
                       projected_end=10.0, slot_headroom=2)
    profiles = {"fit": FusionProfile(("k",), slots=1, tokens=64.0),
                "big": FusionProfile(("k",), slots=1, tokens=64.0)}
    sched = plan_fused([t_fit, t_big], 4, [0.0] * 4, [rep], profiles)
    assert sched.fused == {"fit": "h"}          # fits inside projected end
    assert {p.task.name for p in sched.placements} == {"big"}
    sched.validate_fused(4, [rep])
    excl = solve_residual([t_fit, t_big], 4, [0.0] * 4, "cp", 9)
    assert sched.makespan <= excl.makespan + 1e-9
    assert lower_bound_fused([t_fit, t_big], 4, [0.0] * 4, [rep],
                             profiles) <= sched.makespan + 1e-9


def test_plan_fused_respects_budgets():
    """Each budget dimension independently blocks fusion: key mismatch,
    slot headroom, token/rank memory budget, projected-end overhang."""
    t = TaskSpec("t", duration=5.0, gpus=2)
    prof = {"t": FusionProfile(("k",), slots=1, tokens=100.0,
                               rank_tokens=400.0)}

    def rep(**kw):
        base = dict(host="h", fuse_key=("k",), gpu_ids=(0, 1),
                    projected_end=10.0, slot_headroom=2,
                    mem_budget=float("inf"), k1=0.0, k2=0.0)
        base.update(kw)
        return ReplicaState(**base)

    assert plan_fused([t], 4, [0.0] * 4, [rep()], prof).fused == {"t": "h"}
    for blocked in (rep(fuse_key=("other",)),        # key mismatch
                    rep(slot_headroom=0),            # no slot
                    rep(projected_end=3.0),          # would extend replica
                    rep(mem_budget=50.0, k1=1.0),    # token budget
                    rep(mem_budget=300.0, k2=1.0)):  # rank-token budget
        assert plan_fused([t], 4, [0.0] * 4, [blocked], prof).fused == {}


def fusionplan_workload(G=2):
    return colo_workload(G)


def run_fusionplan(tasks, G, fusion_planning, migrate=False):
    specs = [s for s, _, _ in tasks]
    plan = solve(specs, G, "cp")
    static = execute_static(plan, G, {s.name: f for s, f, _ in tasks})
    rt = ElasticClusterRuntime(G, colocate=True,
                               fusion_planning=fusion_planning,
                               migrate=migrate)
    for s, f, c in tasks:
        rt.submit(s, f, colo=c)
    return plan, static, rt.run(initial=plan)


def test_fusion_planning_fuses_and_matches_guarantee():
    """With fusion_planning the solver itself assigns pending tasks to
    replica slots; the result keeps elastic <= static and delivers every
    task's result."""
    G = 2
    _, static, rep = run_fusionplan(fusionplan_workload(G), G,
                                    fusion_planning=True, migrate=True)
    assert rep.colocated == {"s1": "host", "s2": "host"}
    assert EventKind.TASK_FUSED in {e.kind for e in rep.events}
    assert rep.makespan <= static.makespan + 1e-9
    assert set(rep.results) == {"host", "hog", "s1", "s2"}
    rep.realized.validate(G)


def _mig_task(rt, name, *, K, Z, total, warm, gpus, exits=None, colo=True,
              at=0.0, key=("arch", 2, "ce")):
    spec, factory = make_task(name, K=K, Z=Z, total=total, warm=warm,
                              step_time=1.0, gpus=gpus, exits=exits or {})
    c = sim_colo_spec(key, K=K, Z=Z, replica_slots=8) if colo else None
    rt.submit(spec, factory, at=at, colo=c)


def test_migration_moves_guest_to_sibling_replica():
    """A guest whose collapsed host would otherwise pin its GPUs migrates
    to a same-fuse-key sibling replica, freeing the host's GPUs for the
    queue — without delaying the guest."""
    rt = ElasticClusterRuntime(4, fusion_planning=True, migrate=True,
                               delay_delta=2.0)
    _mig_task(rt, "a", K=8, Z=4, total=80, warm=10, gpus=2,
              exits={j: 11 for j in range(8)})     # host collapses early
    _mig_task(rt, "b", K=8, Z=4, total=80, warm=10, gpus=2)  # sibling
    _mig_task(rt, "g", K=4, Z=4, total=60, warm=10, gpus=2)  # the guest
    _mig_task(rt, "d", K=4, Z=2, total=30, warm=10, gpus=2,
              colo=False, at=5.0)                  # queue pressure
    rep = rt.run()
    assert rep.migrations == 1
    mig = [e for e in rep.events if e.kind is EventKind.TASK_MIGRATED]
    assert [e.task for e in mig] == ["g"] and "a->b" in mig[0].detail
    assert rep.colocated["g"] == "b"               # final host updated
    # the freed GPUs went to the queued task at the migration instant
    assert rep.task_starts["d"] == pytest.approx(mig[0].time)
    # migration never delayed the guest: it finished with continuous
    # progress (end - start == its solo duration under its exits)
    assert set(rep.results) == {"a", "b", "g", "d"}

    # baseline without migration: the queued task waits for the guest
    rt0 = ElasticClusterRuntime(4, fusion_planning=True, migrate=False,
                                delay_delta=2.0)
    _mig_task(rt0, "a", K=8, Z=4, total=80, warm=10, gpus=2,
              exits={j: 11 for j in range(8)})
    _mig_task(rt0, "b", K=8, Z=4, total=80, warm=10, gpus=2)
    _mig_task(rt0, "g", K=4, Z=4, total=60, warm=10, gpus=2)
    _mig_task(rt0, "d", K=4, Z=2, total=30, warm=10, gpus=2,
              colo=False, at=5.0)
    rep0 = rt0.run()
    assert rep.task_starts["d"] < rep0.task_starts["d"] - 1e-9
    assert rep.makespan <= rep0.makespan + 1e-9
    assert rep.task_ends["g"] <= rep0.task_ends["g"] + 1e-9


def test_preemption_resumes_with_progress_intact():
    """With no sibling replica, the overhanging guest is preempted and
    resumed exclusively — continuing from its suspended progress, never
    restarting, and never finishing later than staying fused."""
    rt = ElasticClusterRuntime(4, fusion_planning=True, migrate=True,
                               delay_delta=2.0)
    _mig_task(rt, "a", K=8, Z=4, total=80, warm=10, gpus=2,
              exits={j: 11 for j in range(8)})
    _mig_task(rt, "c", K=2, Z=2, total=90, warm=10, gpus=2, colo=False)
    _mig_task(rt, "g", K=4, Z=4, total=60, warm=10, gpus=2)
    _mig_task(rt, "d", K=4, Z=2, total=30, warm=10, gpus=2,
              colo=False, at=6.0)
    rep = rt.run()
    assert rep.preemptions == 1
    pre = [e for e in rep.events if e.kind is EventKind.TASK_PREEMPTED]
    assert [e.task for e in pre] == ["g"]
    resumed = [e for e in rep.events
               if e.kind is EventKind.TASK_STARTED and e.task == "g"
               and "resumed" in e.detail]
    assert len(resumed) == 1
    # continuous progress: completion == resume point + suspended residual
    assert rep.task_ends["g"] == pytest.approx(resumed[0].time + 40.0)
    # task_starts keeps the ORIGINAL start (it fused at t=0)
    assert rep.task_starts["g"] == pytest.approx(0.0)
    assert set(rep.results) == {"a", "c", "g", "d"}


def test_residual_refreshed_after_guest_departure():
    """BUGFIX: a hosted guest's cancellation must immediately shrink the
    host's projected-end residual — the anomaly guard and the skyline
    must see post-departure occupancy, not the stale fused projection."""
    G = 1
    rt = ElasticClusterRuntime(G, colocate=True)
    key = ("k", 1, "sft")
    # host collapses early (all kept jobs exit at step 12 -> done ~t=22)
    spec_h, fac_h = make_task("host", K=8, Z=4, total=100, warm=10,
                              step_time=1.0, gpus=1,
                              exits={j: 12 for j in range(8)})
    # long guest pins the replica's projected end; short guest keeps the
    # replica alive after the long one is cancelled
    spec_g, fac_g = make_task("g", K=2, Z=2, total=90, warm=10,
                              step_time=1.0, gpus=1, exits={})
    spec_g2, fac_g2 = make_task("g2", K=2, Z=2, total=50, warm=10,
                                step_time=1.0, gpus=1, exits={})
    rt.submit(spec_h, fac_h, colo=sim_colo_spec(key, K=8, Z=4,
                                                replica_slots=8))
    rt.submit(spec_g, fac_g, colo=sim_colo_spec(key, K=2, Z=2))
    rt.submit(spec_g2, fac_g2, colo=sim_colo_spec(key, K=2, Z=2))
    rt.begin()
    while rt.now < 30.0:                        # past the host's collapse
        assert rt.step()
    assert rt._hosted.get("g") == "host"        # guests fused at t=0
    assert rt._hosted.get("g2") == "host"
    before = rt._running["host"].residual       # pinned by g (ends ~90)
    rt.cancel("g")
    while not rt.is_cancelled("g"):
        assert rt.step()
    run = rt._running["host"]
    est = run.driver.residual_estimate()        # g-free projection
    assert run.residual == pytest.approx(min(est, before))
    assert run.residual < before - 1e-9         # the long guest left
    while rt.step():
        pass
    rep = rt.report()
    assert {"host", "g2"} <= set(rep.results)


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 10_000), G=st.sampled_from([2, 4]))
def test_property_fusion_planning_never_worse_than_static(seed, G):
    """Acceptance property: fusion-AWARE elastic plans (planned fusion +
    migration enabled) never exceed the static exclusive makespan."""
    rng = np.random.default_rng(seed)
    tasks = []
    for i, (spec, factory) in enumerate(random_workload(rng, G)):
        colo = None
        if rng.random() < 0.7:
            drv = factory()
            colo = sim_colo_spec(("shared", spec.gpus), K=drv.K, Z=drv.Z)
        tasks.append((spec, factory, colo))
    specs = [s for s, _, _ in tasks]
    plan = solve(specs, G, "cp")
    static = execute_static(plan, G, {s.name: f for s, f, _ in tasks})
    rt = ElasticClusterRuntime(G, fusion_planning=True, migrate=True)
    for s, f, c in tasks:
        rt.submit(s, f, colo=c)
    rep = rt.run(initial=plan)
    assert rep.makespan <= static.makespan + 1e-9
    rep.realized.validate(G)
    assert set(rep.results) == {s.name for s, _, _ in tasks}
