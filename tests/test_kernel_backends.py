"""Model-level backend equivalence: full forward/train-step math must be
identical between the XLA reference paths and the Pallas kernels
(interpret mode) — attention (flash), linear scan (wkv/ssd), grouped LoRA."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lora as LORA
from repro.core.losses import sft_loss
from repro.models import backend as BK
from repro.models import model as M
from tests.conftest import reduced_f32

ARCHS = ["stablelm-3b", "rwkv6-3b", "hymba-1.5b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_matches_between_backends(arch):
    cfg = reduced_f32(arch)
    Z, b, S = 2, 1, 32
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    lt = LORA.init_lora_tree(key, cfg, Z, jnp.array([4, 8]),
                             M.target_shapes(cfg))
    lt = jax.tree_util.tree_map(lambda x: x + 0.01, lt)
    tokens = jax.random.randint(key, (Z, b, S), 0, cfg.vocab_size)
    h_jnp, _, _ = M.forward(cfg, params, lt, tokens, remat=False)
    with BK.backend("pallas_interpret"):
        h_pal, _, _ = M.forward(cfg, params, lt, tokens, remat=False)
    np.testing.assert_allclose(np.asarray(h_jnp), np.asarray(h_pal),
                               rtol=5e-4, atol=5e-4)


def test_loss_and_grads_match_between_backends():
    cfg = reduced_f32("stablelm-3b")
    Z, b, S = 2, 1, 32
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    lt = LORA.init_lora_tree(key, cfg, Z, jnp.array([4, 8]),
                             M.target_shapes(cfg))
    lt = jax.tree_util.tree_map(lambda x: x + 0.01, lt)
    tokens = jax.random.randint(key, (Z, b, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    active = jnp.ones((Z,), jnp.int32)

    def loss(lora_):
        return sft_loss(cfg, params, lora_, batch, active, remat=False)[0]

    l0, g0 = jax.value_and_grad(loss)(lt)
    with BK.backend("pallas_interpret"):
        l1, g1 = jax.value_and_grad(loss)(lt)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4)
    for a, b_ in zip(jax.tree_util.tree_leaves(g0),
                     jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-3, atol=2e-3)
